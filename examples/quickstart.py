"""Quickstart: the paper's technique end to end in five minutes on CPU.

1. Model the machine (hop-distance topology) as a `Machine`.
2. Compute the paper's core priorities; the `"paper"` binding compiles
   them into a thread→core map.
3. Run the NANOS simulator on a BOTS workload: baseline Nanos vs the
   paper's NUMA-aware execution context — two declarative contexts.
4. Sweep a whole figure grid with one `Machine.grid` call.
5. Route MoE tokens with locality-aware overflow stealing (the SPMD
   adaptation of DFWSPT).
6. Train a tiny LM for a few steps with the full production loop.

    PYTHONPATH=src python examples/quickstart.py [--sim-only]
"""

import argparse

from repro.core import priority, topology
from repro.core.sim import Machine, bots


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sim-only", action="store_true",
                    help="CI smoke: skip the jax-heavy steps (MoE "
                         "routing + training)")
    sim_only = ap.parse_args(argv).sim_only

    # -- 1. the paper's machine ---------------------------------------
    topo = topology.sunfire_x4600()
    m = Machine(topo)
    print(f"machine: {topo.name}: {topo.num_cores} cores / "
          f"{topo.num_nodes} NUMA nodes, ≤{topo.max_distance()} hops")

    # -- 2. priorities (Figs 2–4) + thread binding --------------------
    pr = priority.priorities(topo)
    ctx = m.context(threads=16, binding="paper", placement="spill:2")
    print(f"core priorities: min={pr.total.min():.1f} "
          f"max={pr.total.max():.1f}")
    print(f"master core: {ctx.master_core} (node {ctx.master_node}) — "
          f"the topology centroid; root arrays spill over nodes "
          f"{ctx.root_data_nodes}")

    # -- 3. simulator: baseline Nanos vs the paper --------------------
    # Two declarative execution contexts: baseline Nanos (threads in OS
    # enumeration order and unbound, runtime + root data first-touched
    # on node 0) vs the paper's (priority binding, local runtime data,
    # spill from the master's node). One shared serial reference.
    wl = bots.fft(n=1 << 14, cutoff=4)
    serial = m.serial_time(wl, placement="spill:2@0")
    base = m.run(wl, "wf", seed=0, serial_reference=serial,
                 threads=16, binding="linear", placement="spill:2@0",
                 runtime_data=0, migration_rate=0.15)
    numa = m.run(wl, "dfwspt", seed=0, serial_reference=serial,
                 context=ctx)
    print(f"FFT@16: baseline wf {base.speedup:.2f}x → "
          f"NUMA-aware DFWSPT {numa.speedup:.2f}x "
          f"({(numa.speedup/base.speedup-1)*100:+.1f}%)")

    # -- 4. a whole paper figure as one declarative grid --------------
    grid = m.grid(workloads=[wl], schedulers=("wf", "dfwspt", "dfwsrpt"),
                  threads=(4, 16), placements=("spill:2",),
                  serial_reference=serial)
    res = grid.run()    # one batched engine call, {GridKey: SimResult}
    row = " ".join(f"{k.scheduler}@{k.threads}={r.speedup:.2f}x"
                   for k, r in res.items())
    print(f"grid ({len(res)} cells, 1 batched call): {row}")

    if sim_only:
        print("(--sim-only: skipping MoE routing + training steps)")
        return

    # -- 5. the SPMD adaptation: locality-aware MoE overflow ----------
    import jax
    import numpy as np

    from repro.core.routing import RoutingConfig, expert_steal_table, route

    pod = topology.tpu_pod_2d(4, 4)
    table = expert_steal_table(pod, np.arange(16), "dfwspt")
    logits = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
    logits = logits.at[:, :3].add(3.0)          # hot experts
    vanilla = route(logits, RoutingConfig(16, 1, 16, steal_attempts=0))
    local = route(logits, RoutingConfig(16, 1, 16, steal_attempts=3), table)
    print(f"MoE overflow: drop {float(vanilla['drop_fraction']):.1%} "
          f"→ {float(local['drop_fraction']):.1%} with nearest-first "
          f"stealing")

    # -- 6. the production loop at toy scale --------------------------
    from repro.launch import train

    print("\ntraining a reduced qwen2.5 for 30 steps:")
    train.main(["--arch", "qwen2.5-3b", "--reduced", "--steps", "30",
                "--global-batch", "4", "--seq-len", "64",
                "--lr", "2e-3", "--warmup", "5", "--log-every", "10"])


if __name__ == "__main__":
    main()
