"""Quickstart: the paper's technique end to end in five minutes on CPU.

1. Model the machine (hop-distance topology).
2. Compute the paper's core priorities and bind "threads" (mesh slots).
3. Run the NANOS simulator on a BOTS workload: baseline vs NUMA-aware.
4. Route MoE tokens with locality-aware overflow stealing (the SPMD
   adaptation of DFWSPT).
5. Train a tiny LM for a few steps with the full production loop.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import placement, priority, topology
from repro.core.routing import RoutingConfig, expert_steal_table, route
from repro.core.sim import bots, serial_time, simulate
from repro.launch import train


def main():
    # -- 1. the paper's machine ---------------------------------------
    topo = topology.sunfire_x4600()
    print(f"machine: {topo.name}: {topo.num_cores} cores / "
          f"{topo.num_nodes} NUMA nodes, ≤{topo.max_distance()} hops")

    # -- 2. priorities (Figs 2–4) + thread binding --------------------
    pr = priority.priorities(topo)
    alloc = priority.allocate_threads(topo, 16)
    print(f"core priorities: min={pr.total.min():.1f} "
          f"max={pr.total.max():.1f}")
    print(f"master core: {alloc[0]} (node {topo.core_node[alloc[0]]}) — "
          f"the topology centroid")

    # -- 3. simulator: baseline Nanos vs the paper --------------------
    wl = bots.fft(n=1 << 14, cutoff=4)
    spill0 = placement.first_touch_spill(topo, 0, 2)
    serial = serial_time(topo, wl, 0, spill0)
    base = simulate(topo, list(range(16)), wl, "wf", seed=0,
                    root_data_nodes=spill0, runtime_data_node=0,
                    migration_rate=0.15, serial_reference=serial)
    mn = int(topo.core_node[alloc[0]])
    spill = placement.first_touch_spill(topo, mn, 2, pr)
    numa = simulate(topo, alloc, wl, "dfwspt", seed=0,
                    root_data_nodes=spill, serial_reference=serial)
    print(f"FFT@16: baseline wf {base.speedup:.2f}x → "
          f"NUMA-aware DFWSPT {numa.speedup:.2f}x "
          f"({(numa.speedup/base.speedup-1)*100:+.1f}%)")

    # -- 4. the SPMD adaptation: locality-aware MoE overflow ----------
    pod = topology.tpu_pod_2d(4, 4)
    table = expert_steal_table(pod, np.arange(16), "dfwspt")
    logits = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
    logits = logits.at[:, :3].add(3.0)          # hot experts
    vanilla = route(logits, RoutingConfig(16, 1, 16, steal_attempts=0))
    local = route(logits, RoutingConfig(16, 1, 16, steal_attempts=3), table)
    print(f"MoE overflow: drop {float(vanilla['drop_fraction']):.1%} "
          f"→ {float(local['drop_fraction']):.1%} with nearest-first "
          f"stealing")

    # -- 5. the production loop at toy scale --------------------------
    print("\ntraining a reduced qwen2.5 for 30 steps:")
    train.main(["--arch", "qwen2.5-3b", "--reduced", "--steps", "30",
                "--global-batch", "4", "--seq-len", "64",
                "--lr", "2e-3", "--warmup", "5", "--log-every", "10"])


if __name__ == "__main__":
    main()
