"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps with checkpointing and exact resume.

This is the deliverable-(b) end-to-end example. The config is a scaled
stablelm-family model (~100M params: 12L, d=768, 12H, ff=2048, 32k
vocab); on the CPU container it runs a shortened schedule by default —
pass --steps 300 for the full run.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import os
import tempfile

from repro import configs
from repro.launch import train


def lm_100m():
    base = configs.get("stablelm-1.6b")
    return dataclasses.replace(
        base,
        name="stablelm-100m",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=2048,
        vocab_size=32768,
        dtype="float32",
        remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    cfg = lm_100m()
    from repro.models import model
    print(f"[example] {cfg.name}: {model.param_count(cfg)/1e6:.1f}M params")

    # register the custom config so the stock driver can use it
    configs.ARCHS[cfg.name] = cfg

    ckpt = os.path.join(tempfile.gettempdir(), "repro_train_lm_ckpt")
    half = args.steps // 2
    print(f"[example] phase 1: steps 0..{half}, checkpointing")
    train.main(["--arch", cfg.name, "--steps", str(half),
                "--global-batch", str(args.global_batch),
                "--seq-len", str(args.seq_len),
                "--lr", "3e-4", "--warmup", "20",
                "--checkpoint-dir", ckpt, "--checkpoint-every", "10",
                "--log-every", "10"])
    print(f"[example] phase 2: auto-resume to {args.steps} "
          f"(simulated restart)")
    loss = train.main(["--arch", cfg.name, "--steps", str(args.steps),
                       "--global-batch", str(args.global_batch),
                       "--seq-len", str(args.seq_len),
                       "--lr", "3e-4", "--warmup", "20",
                       "--checkpoint-dir", ckpt, "--checkpoint-every", "10",
                       "--log-every", "10"])
    print(f"[example] final loss {loss:.4f}")


if __name__ == "__main__":
    main()
