"""Batched serving example: prefill + decode across architectures,
comparing attention-cache vs SSM-state serving costs.

    PYTHONPATH=src python examples/serve_batch.py
"""

from repro.launch import serve


def main():
    for arch in ("qwen2.5-3b", "mamba2-1.3b", "jamba-1.5-large-398b"):
        print(f"\n=== {arch} (reduced config) ===")
        serve.main(["--arch", arch, "--reduced", "--batch", "4",
                    "--prompt-len", "64", "--gen", "16"])


if __name__ == "__main__":
    main()
