"""Fault-tolerance walkthrough: train, kill hosts mid-run, shrink the
mesh with the paper's priority re-placement, restore, continue.

Everything is simulated on CPU, but the decision code (straggler
detection, remesh planning, checkpoint restore) is the production path.

    PYTHONPATH=src python examples/elastic_failover.py
"""

import tempfile

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core import topology
from repro.data import PipelineConfig, TokenPipeline
from repro.models import model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime import Supervisor, plan_elastic_remesh


def main():
    cfg = configs.get("qwen2.5-3b").reduced()
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=5, total_steps=60)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, opt_cfg)
    pipe = TokenPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=8))
    topo = topology.multi_pod(2, 4, 4)     # 32 modeled chips
    state = {"params": params, "opt": opt, "mesh": (4, 8), "losses": []}

    import jax.numpy as jnp

    @jax.jit
    def step_fn(p, o, b):
        (l, _), g = jax.value_and_grad(
            lambda pp: model.train_loss(pp, cfg, b), has_aux=True)(p)
        p, o, _ = adamw_update(g, o, p, opt_cfg)
        return p, o, l

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=2)

        def run_step(s):
            b = pipe.batch_at(s)
            state["params"], state["opt"], l = step_fn(
                state["params"], state["opt"], b)
            state["losses"].append(float(l))
            # host 3 turns into a straggler after step 25
            times = [1.0, 1.0, 1.0, 1.0 if s < 25 else 3.0]
            return times

        def save(s):
            mgr.save_sync(s, {"params": state["params"],
                              "opt": state["opt"]})

        def restore():
            got = mgr.restore_latest({"params": state["params"],
                                      "opt": state["opt"]})
            if got[0] is None:
                return 0
            state["params"] = got[1]["params"]
            state["opt"] = got[1]["opt"]
            return got[0]

        def remesh(plan):
            state["mesh"] = plan.mesh_shape
            print(f"[elastic] new mesh {plan.mesh_shape}, "
                  f"{len(plan.surviving)} devices, "
                  f"DP scale ×{plan.data_parallel_scale:.2f}")

        sup = Supervisor(num_hosts=4, checkpoint_every=10,
                         run_step=run_step, save=save, restore=restore,
                         remesh=remesh, topo=topo, mesh_shape=(4, 8),
                         model_axis_size=8)
        final = sup.run(0, 40, inject_failure={17: [5, 6]})
        print(f"[elastic] finished at step {final}")
        print("[elastic] events:")
        for s, e in sup.events:
            print(f"   step {s:3d}: {e}")
        print(f"[elastic] loss {state['losses'][0]:.3f} → "
              f"{state['losses'][-1]:.3f} over {len(state['losses'])} "
              f"executed steps (incl. replays)")


if __name__ == "__main__":
    main()
