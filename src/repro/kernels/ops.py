"""Public kernel entry points.

Each op pairs a Pallas forward kernel with a backward pass derived from
the pure-jnp oracle (``jax.vjp`` of ref.py) via ``jax.custom_vjp`` — the
kernels stay usable under ``jax.grad`` everywhere. On a real TPU fleet the
attention backward would get its own kernel; that is an optimization, not
a semantics change (EXPERIMENTS.md §Perf notes the expected delta).

``interpret`` resolution: ``None`` → interpret unless running on TPU, so
the same model code runs kernels natively on TPU and in interpret mode in
CPU CI.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_kernel_call
from .moe_gmm import moe_gmm_kernel_call
from .rmsnorm import rmsnorm_kernel_call
from .ssd_scan import ssd_scan_kernel_call

__all__ = ["rmsnorm", "flash_attention", "ssd_scan", "moe_gmm"]


def _resolve_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


# ----------------------------------------------------------------------
# rmsnorm
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm(x2d, w, eps, interpret):
    return rmsnorm_kernel_call(x2d, w, eps=eps, interpret=interpret)


def _rmsnorm_fwd(x2d, w, eps, interpret):
    return _rmsnorm(x2d, w, eps, interpret), (x2d, w)


def _rmsnorm_bwd(eps, interpret, res, g):
    x2d, w = res
    _, vjp = jax.vjp(lambda xx, ww: ref.rmsnorm_ref(xx, ww, eps), x2d, w)
    return vjp(g)


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            interpret: bool | None = None) -> jnp.ndarray:
    """RMSNorm over the last axis; any leading shape."""
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    rows = x2d.shape[0]
    block = rows if rows < 256 or rows % 256 else 256
    out = _rmsnorm(x2d, w, eps, _resolve_interpret(interpret)) \
        if rows % (block or 1) == 0 else ref.rmsnorm_ref(x2d, w, eps)
    return out.reshape(shape)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, scale, window, kv_offset, bq, bk, interpret):
    return flash_attention_kernel_call(
        q, k, v, causal=causal, scale=scale, window=window,
        kv_offset=kv_offset, block_q=bq, block_k=bk, interpret=interpret)


def _flash_fwd(q, k, v, causal, scale, window, kv_offset, bq, bk, interpret):
    out = _flash(q, k, v, causal, scale, window, kv_offset, bq, bk, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, scale, window, kv_offset, bq, bk, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda qq, kk, vv: ref.attention_ref(
            qq, kk, vv, causal=causal, scale=scale, window=window,
            kv_offset=kv_offset), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    window: int | None = None, kv_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """GQA attention, BSHD layout. See flash_attention.py for the design."""
    Sq, Skv = q.shape[1], k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:   # ragged shapes → oracle (CPU/smoke paths)
        return ref.attention_ref(q, k, v, causal=causal, scale=scale,
                                 window=window, kv_offset=kv_offset)
    return _flash(q, k, v, causal, scale, window, kv_offset, bq, bk,
                  _resolve_interpret(interpret))


# ----------------------------------------------------------------------
# ssd scan
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _ssd(x, a, b, c, chunk, interpret):
    return ssd_scan_kernel_call(x, a, b, c, chunk=chunk, interpret=interpret)


def _ssd_fwd(x, a, b, c, chunk, interpret):
    return _ssd(x, a, b, c, chunk, interpret), (x, a, b, c)


def _ssd_bwd(chunk, interpret, res, g):
    x, a, b, c = res
    _, vjp = jax.vjp(
        lambda xx, aa, bb, cc: ref.ssd_ref(xx, aa, bb, cc,
                                           return_state=True),
        x, a, b, c)
    return vjp(g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
             chunk: int = 128, interpret: bool | None = None):
    """Mamba2 SSD over a sequence. Returns (y, final_state)."""
    S = x.shape[1]
    ch = min(chunk, S)
    if S % ch:
        return ref.ssd_ref(x, a, b, c, return_state=True)
    return _ssd(x, a, b, c, ch, _resolve_interpret(interpret))


# ----------------------------------------------------------------------
# grouped expert GEMM
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _gmm(x, w, bc, bf, bd, interpret):
    return moe_gmm_kernel_call(x, w, block_c=bc, block_f=bf, block_d=bd,
                               interpret=interpret)


def _gmm_fwd(x, w, bc, bf, bd, interpret):
    return _gmm(x, w, bc, bf, bd, interpret), (x, w)


def _gmm_bwd(bc, bf, bd, interpret, res, g):
    x, w = res
    _, vjp = jax.vjp(ref.moe_gmm_ref, x, w)
    return vjp(g)


_gmm.defvjp(_gmm_fwd, _gmm_bwd)


def moe_gmm(x: jnp.ndarray, w: jnp.ndarray,
            block_c: int = 128, block_f: int = 128, block_d: int = 128,
            interpret: bool | None = None) -> jnp.ndarray:
    """Per-expert GEMM: (E, C, D) @ (E, D, F) → (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[-1]
    bc, bf, bd = (min(block_c, C), min(block_f, F), min(block_d, D))
    if C % bc or F % bf or D % bd:
        return ref.moe_gmm_ref(x, w)
    return _gmm(x, w, bc, bf, bd, _resolve_interpret(interpret))
