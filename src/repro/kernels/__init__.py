"""Pallas TPU kernels: flash attention, SSD scan, MoE grouped GEMM,
RMSNorm. Public API in ops.py; oracles in ref.py."""

from .ops import flash_attention, moe_gmm, rmsnorm, ssd_scan

__all__ = ["flash_attention", "moe_gmm", "rmsnorm", "ssd_scan"]
