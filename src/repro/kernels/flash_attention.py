"""Blocked (FlashAttention-style) attention Pallas kernel for TPU.

TPU-native design, not a CUDA port:
  * grid = (B, Hq, Sq/bq, Skv/bk) with the KV axis innermost — the TPU
    grid is executed sequentially over the minor axis, so the online
    softmax state (m, l, acc) lives in VMEM scratch and is carried
    across KV blocks without any inter-block synchronization primitive
    (no equivalent of CUDA shared-memory staging is needed).
  * block shapes default to (128, 128): MXU-aligned on both matmuls
    (q·kᵀ and p·v), and the f32 accumulator tile (bq × D) stays in VMEM.
  * GQA is handled in the BlockSpec index_map (kv head = hq // group) —
    no repeated K/V materialization in HBM.
  * causal masking compares absolute positions, so the same kernel does
    prefill (Sq == Skv), chunked prefill and decode (Sq == 1) via
    ``kv_offset``; fully-masked KV blocks skip their matmuls with
    ``pl.when`` (the TPU analogue of Flash2's early-exit).

Oracle: :func:`repro.kernels.ref.attention_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            kv_offset: int, block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + kv_offset   # absolute q positions
    k_start = ki * block_k

    # Whole-block skip: for causal layouts, KV blocks strictly above the
    # diagonal contribute nothing — skip both matmuls.
    qpos = q_start + jax.lax.iota(jnp.int32, block_q)
    kpos = k_start + jax.lax.iota(jnp.int32, block_k)
    block_live = True
    if causal:
        block_live = k_start <= q_start + block_q - 1
    if window is not None:
        block_live = jnp.logical_and(
            block_live, k_start + block_k - 1 > q_start - window)

    @pl.when(block_live)
    def _body():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finalize():
        l = l_ref[...]
        # fully-masked rows (decode warm-up) produce l == 0 → emit zeros.
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = out.astype(o_ref.dtype)


def flash_attention_kernel_call(q: jnp.ndarray, k: jnp.ndarray,
                                v: jnp.ndarray,
                                causal: bool = True,
                                scale: float | None = None,
                                window: int | None = None,
                                kv_offset: int = 0,
                                block_q: int = 128,
                                block_k: int = 128,
                                interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} % Hkv={Hkv} != 0")
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    if Sq % block_q or Skv % block_k:
        raise ValueError(f"seq lens ({Sq},{Skv}) not divisible by blocks "
                         f"({block_q},{block_k})")
    scale = (D ** -0.5) if scale is None else scale

    grid = (B, Hq, Sq // block_q, Skv // block_k)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        kv_offset=kv_offset, block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, D),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((None, block_k, None, D),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((None, block_k, None, D),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
