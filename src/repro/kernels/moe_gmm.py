"""Grouped expert GEMM Pallas kernel (MoE hot loop).

Computes out[e] = x[e] @ w[e] for every expert's capacity-dispatched token
block — the compute core of the MoE layer once the locality-aware router
(repro.core.routing) has packed tokens into (E, C, D).

TPU mapping: grid = (E, C/bc, F/bf, D/bd), f32 accumulator tile (bc × bf)
in VMEM carried over the inner D axis; every matmul is MXU-shaped
(bc, bd) × (bd, bf) with 128-aligned defaults. Experts ride the outermost
grid axis so each expert's weight tile streams HBM→VMEM exactly once per
(ci, fi) tile — the layout a GPU grouped-GEMM achieves with CTA swizzling
falls out of the grid order here.

Oracle: :func:`repro.kernels.ref.moe_gmm_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["moe_gmm_kernel_call"]


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(di == pl.num_programs(3) - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_kernel_call(x: jnp.ndarray, w: jnp.ndarray,
                        block_c: int = 128, block_f: int = 128,
                        block_d: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """x: (E, C, D) dispatched tokens; w: (E, D, F). Returns (E, C, F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    for name, dim, blk in (("C", C, block_c), ("F", F, block_f),
                           ("D", D, block_d)):
        if dim % blk:
            raise ValueError(f"{name}={dim} not divisible by block {blk}")
    grid = (E, C // block_c, F // block_f, D // block_d)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((None, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((None, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
