"""Fused RMSNorm Pallas kernel.

One pass over rows resident in VMEM: mean-square, rsqrt, scale — no
intermediate HBM round-trips (XLA typically fuses this too; the kernel
exists to pin the layout and as the simplest template of the package's
kernel/ops/ref pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_kernel_call"]


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_kernel_call(x: jnp.ndarray, w: jnp.ndarray,
                        eps: float = 1e-6,
                        block_rows: int = 256,
                        interpret: bool = False) -> jnp.ndarray:
    """x: (N, D) — callers flatten leading dims; w: (D,)."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"rows {n} not divisible by block_rows {block_rows}")
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        grid=(n // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        interpret=interpret,
    )(x, w)
