"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel for TPU.

The SSD insight: the attention-free recurrence
    h_t = exp(a_t)·h_{t-1} + B_t ⊗ x_t ;   y_t = C_t·h_t
splits into (i) dense intra-chunk matmuls that run on the MXU and
(ii) a tiny inter-chunk state recurrence. TPU-native mapping:

  * grid = (B, H, S/L) with the chunk axis innermost — the sequential
    TPU grid carries the (N × P) chunk state in VMEM scratch, so the
    inter-chunk recurrence costs one multiply-add per chunk with no
    HBM traffic (the GPU version ping-pongs states through a separate
    kernel launch).
  * intra-chunk work is three MXU matmuls per chunk:
    (C·Bᵀ ⊙ decay) (L×L), its product with X (L×P), and the chunk-state
    update Bᵀ·(decay ⊙ X) (N×P). L defaults to 128 for MXU alignment.
  * the decay matrix uses the log-cumsum-exp trick in f32; per-head
    scalar decays (Mamba2) keep it rank-1 — exp(Acum_i − Acum_j).

Oracle: :func:`repro.kernels.ref.ssd_ref` (sequential scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel_call"]


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, state_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)          # (L, P)
    a = a_ref[...].astype(jnp.float32)          # (L,)
    b = b_ref[...].astype(jnp.float32)          # (L, N)
    c = c_ref[...].astype(jnp.float32)          # (L, N)

    acum = jnp.cumsum(a)                        # inclusive: A_t = Σ_{s<=t} a_s
    a_tot = acum[-1]

    # --- carried-state contribution: y_inter[t] = exp(A_t)·C_t·h0
    h0 = state_ref[...]                         # (N, P)
    y_inter = jnp.exp(acum)[:, None] * jax.lax.dot(c, h0)        # (L, P)

    # --- intra-chunk (dual/attention-like) term, causal within the chunk:
    # scores[t, s] = (C_t·B_s)·exp(A_t − A_s) for s ≤ t
    logdecay = acum[:, None] - acum[None, :]                     # (L, L)
    tri = jax.lax.iota(jnp.int32, chunk)[:, None] >= \
        jax.lax.iota(jnp.int32, chunk)[None, :]
    # mask before exp: upper-triangle logdecay is positive (overflow risk)
    decay = jnp.exp(jnp.where(tri, logdecay, -jnp.inf))
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ()))) * decay
    y = y_inter + jax.lax.dot(scores, x)
    y_ref[...] = y.astype(y_ref.dtype)

    # --- state update: h' = exp(A_tot)·h0 + Σ_s exp(A_tot − A_s)·B_s ⊗ x_s
    w = jnp.exp(a_tot - acum)[:, None] * b                       # (L, N)
    state_ref[...] = jnp.exp(a_tot) * h0 + \
        jax.lax.dot_general(w, x, (((0,), (0,)), ((), ())))      # (N, P)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _emit_state():
        hout_ref[...] = state_ref[...]


def ssd_scan_kernel_call(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                         c: jnp.ndarray,
                         chunk: int = 128,
                         interpret: bool = False):
    """x: (B, S, H, P); a: (B, S, H); b, c: (B, S, G, N).

    Returns (y, final_state): (B, S, H, P), (B, H, N, P) — matching
    ``ssd_ref(..., return_state=True)`` with h0 = 0.
    """
    B, S, H, P = x.shape
    _, _, G, N = b.shape
    if H % G:
        raise ValueError(f"H={H} % G={G} != 0")
    rep = H // G
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")

    grid = (B, H, S // chunk)
    y, hT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        out_shape=(jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
                   jax.ShapeDtypeStruct((B, H, N, P), jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, None, P),
                         lambda bb, h, ci: (bb, ci, h, 0)),
            pl.BlockSpec((None, chunk, None),
                         lambda bb, h, ci: (bb, ci, h)),
            pl.BlockSpec((None, chunk, None, N),
                         lambda bb, h, ci: (bb, ci, h // rep, 0)),
            pl.BlockSpec((None, chunk, None, N),
                         lambda bb, h, ci: (bb, ci, h // rep, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, chunk, None, P),
                         lambda bb, h, ci: (bb, ci, h, 0)),
            pl.BlockSpec((None, None, N, P),
                         lambda bb, h, ci: (bb, h, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
    return y, hT
