"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: kernels must match them (see
tests/test_kernels.py sweeps), and training uses them for backward passes
(ops.py wires kernels forward + ref-VJP backward).

Layouts:
  attention  — BSHD: q (B, S, Hq, D), k/v (B, S, Hkv, D), GQA via repeat.
  ssd        — x (B, S, H, P), a (B, S, H) log-decay, B/C (B, S, G, N).
  moe_gmm    — x (E, C, D), w (E, D, F).
  rmsnorm    — x (..., D), w (D,).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_ref", "attention_ref", "attention_chunked_ref",
           "ssd_ref", "ssd_chunked_ref", "moe_gmm_ref"]


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True,
                  scale: float | None = None,
                  window: int | None = None,
                  kv_offset: int = 0) -> jnp.ndarray:
    """Multi-head attention with GQA, causal/bidirectional, sliding window.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    kv_offset: absolute position of q[0] minus that of k[0] (decode: the
    query sits at position ``kv_offset`` within the cache).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + kv_offset
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          causal: bool = True,
                          scale: float | None = None,
                          window: int | None = None,
                          kv_offset: int = 0,
                          chunk: int = 1024) -> jnp.ndarray:
    """Memory-bounded attention: scan over query chunks.

    Same semantics as :func:`attention_ref`, but the (Sq × Skv) score
    matrix never materializes beyond one (chunk × Skv) f32 slab — the
    long-sequence prefill path (32k/500k cells) on any backend.
    """
    B, S, H, D = q.shape
    if S % chunk:
        return attention_ref(q, k, v, causal=causal, scale=scale,
                             window=window, kv_offset=kv_offset)
    nc = S // chunk
    qs = jnp.moveaxis(q.reshape(B, nc, chunk, H, D), 1, 0)

    def f(_, inp):
        i, qc = inp
        o = attention_ref(qc, k, v, causal=causal, scale=scale,
                          window=window, kv_offset=kv_offset + i * chunk)
        return None, o

    _, outs = jax.lax.scan(f, None, (jnp.arange(nc), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, D)


def ssd_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
            h0: jnp.ndarray | None = None,
            return_state: bool = False):
    """Mamba2 SSD (state-space dual) semantics via the sequential scan.

    x: (B, S, H, P) inputs (already multiplied by dt).
    a: (B, S, H) per-head log decay (a = -exp(A_log)·dt, ≤ 0).
    b, c: (B, S, G, N) input/output projections, G groups (H % G == 0).
    h0: optional initial state (B, H, N, P).

    h_t = exp(a_t)·h_{t-1} + B_t ⊗ x_t ;  y_t = C_t · h_t
    """
    B, S, H, P = x.shape
    _, _, G, N = b.shape
    if H % G:
        raise ValueError(f"H={H} not a multiple of G={G}")
    rep = H // G
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)   # (B,S,H,N)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, inp):
        xt, at, bt, ct = inp          # (B,H,P), (B,H), (B,H,N), (B,H,N)
        h = jnp.exp(at)[..., None, None] * h + bt[..., None] * xt[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", ct, h)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (xf.transpose(1, 0, 2, 3), af.transpose(1, 0, 2),
         bb.transpose(1, 0, 2, 3), cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)          # (B,S,H,P)
    if return_state:
        return y, hT
    return y


def moe_gmm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Grouped expert GEMM: x (E, C, D) @ w (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                    c: jnp.ndarray,
                    h0: jnp.ndarray | None = None,
                    chunk: int = 128,
                    return_state: bool = False):
    """Chunked (dual-form) SSD — same semantics as :func:`ssd_ref`, but
    MXU-shaped: dense intra-chunk matmuls + a scan over S/chunk chunk
    states. This is the pure-jnp mirror of the Pallas kernel's math and
    the training/prefill path of the Mamba2 layers (the sequential scan
    would put S serialized steps in the HLO)."""
    B, S, H, P = x.shape
    _, _, G, N = b.shape
    if S % chunk or S == 0:
        return ssd_ref(x, a, b, c, h0=h0, return_state=return_state)
    rep = H // G
    L = chunk
    nc = S // L
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def chunk_f(h, inp):
        xc, ac, bc, cx = inp               # (B,L,H,P) (B,L,H) (B,L,H,N) ×2
        acum = jnp.cumsum(ac, axis=1)      # inclusive
        a_tot = acum[:, -1]                # (B,H)
        y_inter = jnp.exp(acum)[..., None] * jnp.einsum(
            "blhn,bhnp->blhp", cx, h)
        logdecay = acum[:, :, None, :] - acum[:, None, :, :]   # (B,L,L,H)
        tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
        # mask BEFORE exp: the upper triangle holds positive values whose
        # exp overflows; inf·0 in the backward would produce NaN grads.
        decay = jnp.exp(jnp.where(tri[None, :, :, None], logdecay, -jnp.inf))
        scores = jnp.einsum("blhn,bmhn->blmh", cx, bc) * decay
        y = y_inter + jnp.einsum("blmh,bmhp->blhp", scores, xc)
        w = jnp.exp(a_tot[:, None] - acum)[..., None] * bc     # (B,L,H,N)
        h = jnp.exp(a_tot)[..., None, None] * h + jnp.einsum(
            "blhn,blhp->bhnp", w, xc)
        return h, y

    resh = lambda t: jnp.moveaxis(
        t.reshape((B, nc, L) + t.shape[2:]), 1, 0)
    hT, ys = jax.lax.scan(
        chunk_f, h0.astype(jnp.float32),
        (resh(xf), resh(af), resh(bb), resh(cc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P).astype(x.dtype)
    if return_state:
        return y, hT
    return y
