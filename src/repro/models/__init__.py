"""Composable model definitions (pattern-scanned stacks)."""

from . import layers, model, stack
from .model import (abstract_params, active_param_count, decode_step,
                    forward, init_params, param_count, prefill, train_loss)

__all__ = ["layers", "model", "stack", "init_params", "abstract_params",
           "forward", "train_loss", "prefill", "decode_step",
           "param_count", "active_param_count"]
