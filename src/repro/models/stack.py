"""Pattern-scanned layer stack.

Every assigned architecture is a repeated *period* of heterogeneous layer
slots (dense: ``[attn]``; jamba: ``[attn, mamba×7]`` with MoE on odd
slots; vision: ``[self×4, cross]``). Per-slot parameters are stacked with
a leading ``repeats`` axis and the whole stack runs under ``jax.lax.scan``
— one traced period regardless of depth (fast compiles, small HLO) and a
natural remat boundary.

Caches (KV / SSM / media-KV) are threaded through the same scan as
``xs``/``ys`` so train, prefill and decode share one code path.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers

Params = dict[str, Any]


def init_stack(key, cfg) -> Params:
    """Stacked per-slot params: each leaf has leading dim R = repeats."""
    R = cfg.repeats

    def init_one_repeat(k):
        slot_params = []
        for si, (kind, ffn) in enumerate(cfg.pattern):
            k, k1, k2, k3, k4 = jax.random.split(k, 5)
            p: Params = {"ln1": layers.rms_weight(cfg.d_model, cfg.param_dtype)}
            if kind == "attn":
                p["mix"] = layers.init_attention(k1, cfg)
            elif kind == "mamba":
                p["mix"] = layers.init_mamba(k1, cfg)
            elif kind == "cross":
                p["mix"] = layers.init_cross_attention(k1, cfg)
            else:
                raise ValueError(f"unknown slot kind {kind!r}")
            if ffn == "moe":
                p["ln2"] = layers.rms_weight(cfg.d_model, cfg.param_dtype)
                p["ffn"] = layers.init_moe(k2, cfg)
            elif ffn == "mlp":
                p["ln2"] = layers.rms_weight(cfg.d_model, cfg.param_dtype)
                p["ffn"] = layers.init_mlp(k3, cfg)
            elif ffn != "none":
                raise ValueError(f"unknown ffn kind {ffn!r}")
            slot_params.append(p)
        return slot_params

    keys = jax.random.split(key, R)
    return jax.vmap(init_one_repeat)(keys)


def init_caches(cfg, batch: int, max_len: int, dtype):
    """Stacked caches per slot (leading dim R); None for stateless slots."""
    R = cfg.repeats
    slots = []
    for kind, _ in cfg.pattern:
        if kind == "attn":
            c = layers.attn_cache_init(cfg, batch, max_len, dtype)
            c.pop("length")
            slots.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape), c))
        elif kind == "mamba":
            c = layers.mamba_cache_init(cfg, batch, dtype)
            slots.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape), c))
        elif kind == "cross":
            stored = cfg.num_kv_heads * cfg.kv_repeat
            c = dict(
                k=jnp.zeros((batch, cfg.num_media_tokens, stored,
                             cfg.head_dim), dtype),
                v=jnp.zeros((batch, cfg.num_media_tokens, stored,
                             cfg.head_dim), dtype),
            )
            slots.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape), c))
        else:
            slots.append(None)
    return dict(length=jnp.zeros((), jnp.int32), slots=slots)


def apply_stack(params, cfg, x, *, positions, media=None, caches=None,
                steal_table=None, mode: str = "train"):
    """Run the stack. mode: 'train' (no caches) | 'prefill' (fill caches)
    | 'decode' (read + update caches). Returns (x, new_caches, aux)."""
    if mode == "train":
        caches = None
    length = caches["length"] if caches is not None else None

    def make_slot_fn(si, kind, ffn):
        def slot_fn(h, p, c):
            hin = layers.rmsnorm(h, p["ln1"], cfg.norm_eps)
            if kind == "attn":
                cc = dict(c, length=length) if c is not None else None
                y, nc = layers.attention(hin, p["mix"], cfg,
                                         positions=positions, cache=cc,
                                         causal=not cfg.is_encoder)
                if nc is not None:
                    nc.pop("length")
            elif kind == "mamba":
                y, nc = layers.mamba(hin, p["mix"], cfg, cache=c)
            elif kind == "cross":
                # prefill projects media into the cache; decode reuses it.
                y, nc = layers.cross_attention(
                    hin, p["mix"], cfg, media=media,
                    cache=c if mode == "decode" else None)
                if caches is None:
                    nc = None
            else:
                raise ValueError(kind)
            h = h + y
            aux = jnp.zeros((), jnp.float32)
            if ffn != "none":
                hin = layers.rmsnorm(h, p["ln2"], cfg.norm_eps)
                if ffn == "moe":
                    y, aux = layers.moe(hin, p["ffn"], cfg, steal_table)
                else:
                    y = layers.mlp(hin, p["ffn"])
                h = h + y
            return h, nc, aux
        if cfg.remat != "none" and mode == "train" and len(cfg.pattern) > 1:
            # nested remat (multi-slot periods only): the period checkpoint
            # bounds what the scan saves; the per-slot checkpoint bounds
            # the *backward* live set to one slot's internals at a time.
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat == "full" else
                      jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            return jax.checkpoint(slot_fn, policy=policy, prevent_cse=False)
        return slot_fn

    slot_fns = [make_slot_fn(si, kind, ffn)
                for si, (kind, ffn) in enumerate(cfg.pattern)]

    def period_body(carry, xs):
        h, aux = carry
        slot_params, slot_caches = xs
        new_slot_caches = []
        for si in range(len(cfg.pattern)):
            p = slot_params[si]
            if cfg.serialize_slot_gathers and si > 0:
                # gate this slot's weight reads on the running activation:
                # FSDP gathers then happen at use, not all at period top.
                p = jax.tree.map(
                    lambda w: jax.lax.optimization_barrier((w, h))[0], p)
            c = slot_caches[si] if slot_caches is not None else None
            h, nc, a = slot_fns[si](h, p, c)
            aux = aux + a
            new_slot_caches.append(nc)
        return (h, aux), new_slot_caches

    body = period_body
    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(period_body, policy=policy,
                              prevent_cse=False)

    slot_caches_xs = caches["slots"] if caches is not None else \
        [None for _ in cfg.pattern]
    (x, aux), new_slots = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params, slot_caches_xs))
    new_caches = None
    if caches is not None:
        new_caches = dict(length=length + x.shape[1], slots=new_slots)
    return x, new_caches, aux
