"""Model building blocks: norms, RoPE, GQA/cross attention, SwiGLU MLP,
MoE with locality-aware routing, Mamba2 (SSD) mixer.

Conventions:
  * pure functions over param dicts (no module framework);
  * activations (B, S, D); attention BSHD; params created by init_* fns;
  * every mixer returns ``(y, new_cache)`` where cache is ``None`` for
    stateless training, so the same code path serves train / prefill /
    decode;
  * f32 for softmax/normalizer math, params/activations in cfg dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import RoutingConfig, route
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Params = dict[str, Any]


def _constrain(x, spec):
    """Apply a sharding constraint from a config-carried spec tuple.

    ``spec`` is a tuple of (axis-name | tuple | None) per dim, set by the
    launcher per mesh (None config field = no constraint). Requires an
    ambient mesh (jit under ``with mesh:``); no-op otherwise.
    """
    if spec is None:
        return x
    from jax.sharding import PartitionSpec as P
    import jax as _jax
    try:
        return _jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x  # no ambient mesh (single-device smoke paths)


# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def _dense_init(key, d_in, d_out, dtype, scale=None):
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def rms_weight(d, dtype):
    return jnp.ones((d,), dtype)


# ----------------------------------------------------------------------
# norms / rope
# ----------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6, use_kernel=False):
    if use_kernel:
        return kops.rmsnorm(x, w, eps)
    return kref.rmsnorm_ref(x, w, eps)


def rope(x, positions, theta):
    """x: (B, S, H, D); positions: (B, S). Rotates pairs (d, d + D/2)."""
    B, S, H, D = x.shape
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention (self, GQA, optional qk-norm / bias; cross variant)
# ----------------------------------------------------------------------

def init_attention(key, cfg) -> Params:
    ks = jax.random.split(key, 6)
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    p = {
        "wq": _dense_init(ks[0], D, H * Dh, dt),
        "wk": _dense_init(ks[1], D, Hkv * Dh, dt),
        "wv": _dense_init(ks[2], D, Hkv * Dh, dt),
        "wo": _dense_init(ks[3], H * Dh, D, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dt)
        p["bk"] = jnp.zeros((Hkv * Dh,), dt)
        p["bv"] = jnp.zeros((Hkv * Dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = rms_weight(Dh, dt)
        p["k_norm"] = rms_weight(Dh, dt)
    return p


def attention(x, p, cfg, *, positions, cache=None, causal=True):
    """Self attention. cache: None | dict(k, v, length: scalar int32).

    Training/prefill: full-sequence q over full k/v (cache written if
    provided). Decode: S == 1 query against cache (k/v updated in place).
    """
    B, S, D = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.kv_repeat > 1:
        # pre-replicate kv heads so stored heads divide the TP axis
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    q = _constrain(q, cfg.attn_q_spec)

    new_cache = None
    if cache is None:
        kk, vv, kv_off = k, v, 0
        kk = _constrain(kk, cfg.attn_kv_spec)
        vv = _constrain(vv, cfg.attn_kv_spec)
    else:
        length = cache["length"]                      # scalar int32
        kk = jax.lax.dynamic_update_slice(cache["k"], k, (0, length, 0, 0))
        vv = jax.lax.dynamic_update_slice(cache["v"], v, (0, length, 0, 0))
        kk = _constrain(kk, cfg.attn_kv_spec)
        vv = _constrain(vv, cfg.attn_kv_spec)
        new_cache = dict(k=kk, v=vv, length=length + S)
        kv_off = length

    if cfg.attn_impl == "kernel" and cache is None:
        out = kops.flash_attention(q, kk, vv, causal=causal,
                                   window=cfg.attn_window)
    elif S >= cfg.attn_chunk_threshold:
        # long prefill/training: bound the score slab to (chunk × Skv)
        out = kref.attention_chunked_ref(
            q, kk, vv, causal=causal or cache is not None,
            window=cfg.attn_window, kv_offset=_kv_offset(kv_off, cache),
            chunk=cfg.attn_chunk)
    else:
        # decode path masks positions ≥ length + S via the causal mask on
        # absolute positions (cache tail is zeros but masked out).
        out = kref.attention_ref(q, kk, vv, causal=causal or cache is not None,
                                 window=cfg.attn_window,
                                 kv_offset=_kv_offset(kv_off, cache))
    out = out.reshape(B, S, H * Dh) @ p["wo"]
    return out, new_cache


def _kv_offset(kv_off, cache):
    # with a cache, q absolute position = previous length (traced scalar
    # is fine — attention_ref builds the mask from it)
    return kv_off


def init_cross_attention(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    return {
        "wq": _dense_init(ks[0], D, H * Dh, dt),
        "wk": _dense_init(ks[1], D, Hkv * Dh, dt),
        "wv": _dense_init(ks[2], D, Hkv * Dh, dt),
        "wo": _dense_init(ks[3], H * Dh, D, dt),
        "q_norm": rms_weight(Dh, dt),
        "k_norm": rms_weight(Dh, dt),
        "gate": jnp.zeros((1,), dt),     # llama3.2-vision gated cross-attn
    }


def cross_attention(x, p, cfg, *, media, cache=None):
    """Cross attention onto media embeddings (B, M, D_model).

    cache: None | dict(k, v) of projected media (decode reuses them).
    """
    B, S, D = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    q = _constrain(q, cfg.attn_q_spec)
    if cache is None:
        M = media.shape[1]
        k = (media @ p["wk"]).reshape(B, M, Hkv, Dh)
        v = (media @ p["wv"]).reshape(B, M, Hkv, Dh)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        if cfg.kv_repeat > 1:
            k = jnp.repeat(k, cfg.kv_repeat, axis=2)
            v = jnp.repeat(v, cfg.kv_repeat, axis=2)
        new_cache = dict(k=k, v=v)
    else:
        k, v = cache["k"], cache["v"]
        new_cache = cache
    out = kref.attention_ref(q, k, v, causal=False)
    out = out.reshape(B, S, H * Dh) @ p["wo"]
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out, \
        new_cache


# ----------------------------------------------------------------------
# MLP / MoE
# ----------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None) -> Params:
    ks = jax.random.split(key, 3)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    return {
        "wg": _dense_init(ks[0], D, F, dt),
        "wu": _dense_init(ks[1], D, F, dt),
        "wd": _dense_init(ks[2], F, D, dt),
    }


def mlp(x, p):
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def init_moe(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    D, E = cfg.d_model, cfg.moe_num_experts
    F = cfg.moe_d_ff or cfg.d_ff
    dt = cfg.param_dtype
    p = {
        "router": _dense_init(ks[0], D, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(dt),
        "wu": (jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)).astype(dt),
        "wd": (jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)).astype(dt),
    }
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff)
    return p


def moe(x, p, cfg, steal_table=None):
    """Mixture of experts over (B, S, D) with locality-aware overflow.

    Tokens are routed in groups of ``cfg.moe_group`` (GShard-style) so the
    dispatch tensors stay bounded; the router's overflow re-routing walks
    the topology steal table (the paper's scheduler, see core/routing.py).
    Returns (y, aux_loss).
    """
    B, S, D = x.shape
    E = cfg.moe_num_experts
    T = B * S
    xf = x.reshape(T, D)
    G = min(cfg.moe_group, T)
    ngroups = T // G
    xg = xf.reshape(ngroups, G, D)
    xg = _constrain(xg, cfg.moe_group_spec)
    capacity = int(np.ceil(G * cfg.moe_top_k * cfg.capacity_factor / E))
    capacity = max(capacity, cfg.moe_top_k)
    rcfg = RoutingConfig(num_experts=E, top_k=cfg.moe_top_k,
                         capacity=capacity,
                         steal_attempts=cfg.moe_steal_attempts,
                         policy=cfg.moe_steal_policy)

    table = steal_table
    if rcfg.steal_attempts > 0 and table is None:
        # fallback: ring order (expert e steals from e±1, e±2, ...)
        idx = np.arange(E)
        table = np.stack([np.concatenate([
            (e + np.arange(1, E)) % E]) for e in idx])

    def route_group(xg1):
        logits = xg1.astype(jnp.float32) @ p["router"]
        r = route(logits, rcfg, table)
        return r["expert"], r["slot"], r["weight"], r["aux_loss"]

    # routing per group (small tensors) …
    expert, slot, weight, aux = jax.vmap(route_group)(xg)
    # … but the heavy dispatch/expert einsums keep the group dim explicit
    # so the sharding constraints apply at the jit level (groups ride the
    # DP axes, experts the model axis — constraints under vmap are not
    # reliably honored by GSPMD).
    e_oh = jax.nn.one_hot(expert, E, dtype=xg.dtype)       # (g,s,K,E)
    c_oh = jax.nn.one_hot(slot, capacity, dtype=xg.dtype)  # (g,s,K,C)
    combine = jnp.einsum("gske,gskc,gsk->gsec", e_oh, c_oh,
                         weight.astype(xg.dtype))
    dispatch = jnp.einsum("gske,gskc->gsec", e_oh, c_oh)
    xin = jnp.einsum("gsec,gsd->gecd", dispatch, xg)       # (g,E,C,D)
    xin = _constrain(xin, cfg.moe_xin_spec)
    if cfg.moe_impl == "kernel":
        flat = xin.reshape(ngroups * E, capacity, D)
        wg_f = jnp.tile(p["wg"], (ngroups, 1, 1))
        wu_f = jnp.tile(p["wu"], (ngroups, 1, 1))
        wd_f = jnp.tile(p["wd"], (ngroups, 1, 1))
        h = jax.nn.silu(kops.moe_gmm(flat, wg_f)) * kops.moe_gmm(flat, wu_f)
        eout = kops.moe_gmm(h, wd_f).reshape(ngroups, E, capacity, D)
    else:
        h = jnp.einsum("gecd,edf->gecf", xin, p["wg"])
        u = jnp.einsum("gecd,edf->gecf", xin, p["wu"])
        h = jax.nn.silu(h) * u
        h = _constrain(h, cfg.moe_h_spec)
        eout = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    eout = _constrain(eout, cfg.moe_xin_spec)
    y = jnp.einsum("gsec,gecd->gsd", combine, eout)
    y = y.reshape(B, S, D)
    if cfg.moe_shared_expert:
        y = y + mlp(x, p["shared"])
    return y, jnp.mean(aux)


# ----------------------------------------------------------------------
# Mamba2 (SSD) mixer
# ----------------------------------------------------------------------

def init_mamba(key, cfg) -> Params:
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = d_inner // cfg.ssm_head_dim
    G, N, K = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    dt = cfg.param_dtype
    conv_dim = d_inner + 2 * G * N
    return {
        "in_proj": _dense_init(ks[0], D, 2 * d_inner + 2 * G * N + H, dt),
        "conv_w": (jax.random.normal(ks[1], (K, conv_dim)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "out_norm": rms_weight(d_inner, dt),
        "out_proj": _dense_init(ks[2], d_inner, D, dt),
    }


def _mamba_split(cfg):
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    return d_inner, G, N, H


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv1d. xbc: (B, S, C); w: (K, C).

    conv_state: (B, K-1, C) previous inputs for decode; returns new state.
    """
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    full = jnp.concatenate([pad, xbc], axis=1)          # (B, S+K-1, C)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(K)) + b
    new_state = full[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(out), new_state


def mamba(x, p, cfg, cache=None):
    """Mamba2 block. cache: None | dict(conv, ssm) for decode.

    Training/prefill: chunked SSD (kernel or ref). Decode (S == 1):
    single-step recurrence.
    """
    B, S, D = x.shape
    d_inner, G, N, H = _mamba_split(cfg)
    P = cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xbc, dtp = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * G * N], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    bmat = bmat.reshape(B, S, G, N)
    cmat = cmat.reshape(B, S, G, N)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])[None, None, :] * dt                  # (B,S,H)
    x_dt = xs * dt[..., None].astype(xs.dtype)
    x_dt = _constrain(x_dt, cfg.ssm_act_spec)

    if cache is None:
        if cfg.ssm_impl == "kernel":
            y, _ = kops.ssd_scan(x_dt, a, bmat, cmat, chunk=cfg.ssm_chunk)
        else:
            y = kref.ssd_chunked_ref(x_dt, a, bmat, cmat,
                                     chunk=cfg.ssm_chunk)
        new_cache = None
    elif S > 1:
        # chunked prefill with carried state
        h0 = cache["ssm"]                                 # (B,H,N,P) f32
        y, hT = kref.ssd_chunked_ref(x_dt, a, bmat, cmat, h0=h0,
                                     chunk=cfg.ssm_chunk,
                                     return_state=True)
        new_cache = dict(conv=new_conv, ssm=hT)
    else:
        h0 = cache["ssm"]
        y, hT = kref.ssd_ref(x_dt, a, bmat, cmat, h0=h0, return_state=True)
        new_cache = dict(conv=new_conv, ssm=hT)
    y = y + xs * p["D_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_cache


def mamba_cache_init(cfg, batch, dtype):
    d_inner, G, N, H = _mamba_split(cfg)
    conv_dim = d_inner + 2 * G * N
    return dict(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, H, N, cfg.ssm_head_dim), jnp.float32),
    )


def attn_cache_init(cfg, batch, max_len, dtype):
    stored = cfg.num_kv_heads * cfg.kv_repeat
    return dict(
        k=jnp.zeros((batch, max_len, stored, cfg.head_dim), dtype),
        v=jnp.zeros((batch, max_len, stored, cfg.head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )
