"""Model facade: init / train loss / forward / prefill / decode.

One implementation covers all ten assigned architectures via the config's
layer pattern (see stack.py). Modality frontends are stubs per the
assignment: audio passes precomputed frame embeddings, VLM passes
precomputed patch embeddings as cross-attention media.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, stack

Params = dict[str, Any]


def init_params(cfg, key) -> Params:
    k_emb, k_stack, k_head = jax.random.split(key, 3)
    D, V = cfg.d_model, cfg.vocab_size
    p: Params = {
        "blocks": stack.init_stack(k_stack, cfg),
        "final_norm": layers.rms_weight(D, cfg.param_dtype),
    }
    if not cfg.embeds_input:
        p["embed"] = (jax.random.normal(k_emb, (V, D)) * 0.02
                      ).astype(cfg.param_dtype)
    if cfg.tie_embeddings and not cfg.embeds_input:
        pass  # reuse p["embed"].T at the head
    else:
        p["lm_head"] = (jax.random.normal(k_head, (D, V)) / np.sqrt(D)
                        ).astype(cfg.param_dtype)
    return p


def abstract_params(cfg, dtype_override=None):
    """ShapeDtypeStruct tree without allocating (dry-run path)."""
    out = jax.eval_shape(lambda k: init_params(cfg, k),
                         jax.random.PRNGKey(0))
    if dtype_override is not None:
        out = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype_override)
            if jnp.issubdtype(s.dtype, jnp.floating) else s, out)
    return out


def _embed(params, cfg, tokens=None, embeds=None):
    if cfg.embeds_input:
        assert embeds is not None, "this arch takes frontend embeddings"
        return embeds.astype(cfg.param_dtype)
    return params["embed"][tokens]


def _head(params, cfg, x):
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and "lm_head" not in params:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    return (x @ w).astype(jnp.float32)


def forward(params, cfg, tokens=None, embeds=None, media=None,
            steal_table=None):
    """Full-sequence logits (training teacher-forcing / encoder forward).

    Returns (logits, aux_loss)."""
    x = _embed(params, cfg, tokens, embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, _, aux = stack.apply_stack(params["blocks"], cfg, x,
                                  positions=positions, media=media,
                                  steal_table=steal_table, mode="train")
    return _head(params, cfg, x), aux


def train_loss(params, cfg, batch, steal_table=None):
    """Cross-entropy (+ router aux + z-loss). batch: dict with
    tokens/embeds, labels (B, S) int32 (-100 = masked), optional media."""
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          media=batch.get("media"),
                          steal_table=steal_table)
    labels = batch["labels"]
    valid = labels >= 0
    labels_safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], -1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = -(ll * valid).sum() / denom
    # z-loss stabilizes the softmax normalizer at scale
    zl = jnp.square(jax.nn.logsumexp(logits, axis=-1))
    z_loss = (zl * valid).sum() / denom
    loss = ce + cfg.router_aux_weight * aux + cfg.z_loss_weight * z_loss
    return loss, dict(ce=ce, aux=aux, z_loss=z_loss)


def prefill(params, cfg, tokens=None, embeds=None, media=None,
            max_len: int | None = None, steal_table=None):
    """Process a prompt, returning (last_logits, caches)."""
    x = _embed(params, cfg, tokens, embeds)
    B, S = x.shape[:2]
    max_len = max_len or S
    caches = stack.init_caches(cfg, B, max_len, cfg.param_dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, caches, _ = stack.apply_stack(params["blocks"], cfg, x,
                                     positions=positions, media=media,
                                     caches=caches, mode="prefill",
                                     steal_table=steal_table)
    return _head(params, cfg, x[:, -1:]), caches


def decode_step(params, cfg, caches, tokens, steal_table=None):
    """One decode step. tokens: (B, 1) int32. Returns (logits, caches)."""
    x = _embed(params, cfg, tokens)
    B = x.shape[0]
    pos = jnp.broadcast_to(caches["length"], (B, 1)).astype(jnp.int32)
    x, caches, _ = stack.apply_stack(params["blocks"], cfg, x,
                                     positions=pos, caches=caches,
                                     mode="decode", steal_table=steal_table)
    return _head(params, cfg, x), caches


def param_count(cfg) -> int:
    tree = abstract_params(cfg)
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(tree))


def active_param_count(cfg) -> int:
    """Params touched per token (MoE: top_k of num_experts)."""
    total = param_count(cfg)
    if cfg.moe_num_experts == 0:
        return total
    tree = abstract_params(cfg)
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "ffn" in keys and any(k in ("wg", "wu", "wd") for k in keys):
            # stacked expert weights (R, slots..., E, D, F)
            if len(leaf.shape) >= 3 and leaf.shape[-3] == cfg.moe_num_experts:
                expert += int(np.prod(leaf.shape))
    active = total - expert + expert * cfg.moe_top_k // cfg.moe_num_experts
    return active
