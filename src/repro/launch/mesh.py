"""Production mesh construction + topology-aware device ordering.

``make_production_mesh`` is the deliverable entry point: 16×16
("data","model") per pod, (2,16,16) ("pod","data","model") across two
pods. With ``topology_aware=True`` the physical device order is permuted
by the paper's priority walk (core/placement.py) before the mesh is
built, so the high-traffic "model" axis lands on minimal-hop ICI rings
and the coordinator (logical position 0) sits at the topology centroid —
the thread→core binding of §IV, chip-granular.

Importing this module never touches jax device state; everything is
behind functions.
"""

from __future__ import annotations

import numpy as np

from repro.core import placement
from repro.core import topology as topo_mod

__all__ = ["make_production_mesh", "production_topology",
           "mesh_steal_table", "coordinator_device", "POD_SHAPE"]

POD_SHAPE = (16, 16)          # 256 chips per v5e pod (2-D ICI torus)


def production_topology(multi_pod: bool = False) -> topo_mod.Topology:
    """Modeled hop-distance topology matching the production mesh.

    Device id d in jax.devices() order corresponds to topology core d
    (pods enumerate consecutively, row-major within a pod).
    """
    if multi_pod:
        return topo_mod.multi_pod(2, *POD_SHAPE)
    return topo_mod.tpu_pod_2d(*POD_SHAPE)


def make_production_mesh(*, multi_pod: bool = False,
                         topology_aware: bool = False,
                         devices=None):
    """Build the production mesh (deliverable (e) entry point)."""
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if not topology_aware:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
            devices=devices)
    devices = np.asarray(devices if devices is not None else jax.devices())
    if devices.size != int(np.prod(shape)):
        raise ValueError(f"need {int(np.prod(shape))} devices, "
                         f"got {devices.size}")
    topo = production_topology(multi_pod)
    perm = placement.device_order_priority(topo, shape)
    grid = devices[perm].reshape(shape)
    return Mesh(grid, axes,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def coordinator_device(mesh):
    """The 'master thread' analogue: checkpoint leader / RNG seeder.

    Logical position (0, ..., 0) — under topology-aware ordering this is
    the highest-priority (most central) chip, so broadcast-style traffic
    (init, restore fan-out) starts from the centroid (first-touch
    analogue).
    """
    return np.asarray(mesh.devices).reshape(-1)[0]


def mesh_steal_table(mesh, num_experts: int, policy: str = "dfwspt",
                     seed: int = 0) -> np.ndarray:
    """Expert steal order for a mesh with experts on the 'model' axis.

    Expert e lives on model-axis block e·M/E (M = model axis size); its
    owning physical chip (representative: pod 0, data row 0) indexes the
    modeled topology. Returns the (E, E-1) table for core/routing.route.
    """
    devs = np.asarray(mesh.devices)
    axes = mesh.axis_names
    model_ax = axes.index("model")
    M = devs.shape[model_ax]
    # representative device per model index: first along all other axes
    index = [0] * devs.ndim
    reps = []
    for m in range(M):
        index[model_ax] = m
        reps.append(devs[tuple(index)].id)
    reps = np.asarray(reps)
    if num_experts >= M:
        if num_experts % M:
            raise ValueError(f"experts {num_experts} % model axis {M} != 0")
        expert_device = reps[(np.arange(num_experts) * M) // num_experts]
    else:
        if M % num_experts:
            raise ValueError(f"model axis {M} % experts {num_experts} != 0")
        expert_device = reps[np.arange(num_experts) * (M // num_experts)]
    multi_pod = "pod" in axes
    topo = production_topology(multi_pod)
    from repro.core.routing import expert_steal_table
    return expert_steal_table(topo, expert_device, policy=policy, seed=seed)
