"""PartitionSpec rules engine.

Assigns NamedShardings to parameter/optimizer/cache/batch trees from
tensor *roles* (inferred from tree paths and shapes) with divisibility
checks and graceful fallback (drop the axis → replicate that dim), so
every (arch × shape × mesh) cell lowers — a hard dry-run requirement.

Strategy (2-D "data" × "model" per pod, +"pod" across pods):
  * batch dims          → ("pod","data")  [DP]
  * TP matrix dims      → "model" (attention heads / MLP hidden / vocab)
  * FSDP: the non-TP matrix dim of every weight → "data"  [ZeRO-3; GSPMD
    inserts the all-gathers at use sites]
  * MoE expert dim      → "model" [EP]
  * KV caches           → batch on ("pod","data"), stored heads on
    "model" (kv_repeat pre-replicates heads when TP > kv heads)
  * optimizer state     → like its parameter (m, v); scalars replicated
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["batch_axes", "fit_spec", "param_specs", "param_shardings",
           "batch_shardings", "cache_shardings", "opt_state_shardings",
           "replicated", "scalar_spec"]


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([_axis_size(mesh, a) for a in axis]))
    return mesh.shape[axis]


def fit_spec(mesh, shape: tuple[int, ...], spec: P) -> P:
    """Drop axes that don't divide their dim (replicate instead)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, (tuple, list)) else (axis,)
        kept: list[str] = []
        for a in axes:
            size = _axis_size(mesh, a)
            cur = int(np.prod([_axis_size(mesh, k) for k in kept]) or 1)
            if size > 1 and dim % (cur * size) == 0:
                kept.append(a)
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def replicated(mesh):
    return NamedSharding(mesh, P())


def scalar_spec(mesh):
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------

def _role_spec(path_keys: list[str], shape: tuple[int, ...],
               profile: str = "2d") -> P:
    """Desired (pre-fallback) spec by role. Shapes may carry a leading
    stacked-repeats dim (params under 'blocks') — caller strips it.

    profile '2d'      — TP on "model" + FSDP on "data" (default).
    profile 'ep_only' — experts on "model", everything else FSDP-only:
    the right layout for small-d_model MoE archs where 16-way TP shards
    are slivers and the TP all-reduces dominate the step (see §Perf).
    """
    name = path_keys[-1] if path_keys else ""
    joined = "/".join(path_keys)

    if profile == "ep_only":
        if len(shape) == 3 and name in ("wg", "wu", "wd"):
            return P("model", "data", None) if name != "wd" \
                else P("model", None, "data")
        if name == "embed":
            return P(("data", "model"), None)
        if name == "lm_head":
            return P(None, ("data", "model"))
        if len(shape) >= 2:
            return P(("data", "model"),)   # pure FSDP over both axes
        return P()

    if profile == "ep_replicated":
        # weight-stationary dense: replicate everything except experts —
        # for MoE archs whose dense tower is tiny, this removes both the
        # TP all-reduces and the ZeRO regathers (§Perf granite iteration).
        if len(shape) == 3 and name in ("wg", "wu", "wd"):
            return P("model", "data", None) if name != "wd" \
                else P("model", None, "data")
        return P()

    if name in ("embed",):                       # (V, D)
        return P("model", "data")
    if name == "lm_head":                        # (D, V)
        return P("data", "model")
    if name in ("wq", "wk", "wv"):               # (D, heads·Dh)
        return P("data", "model")
    if name == "wo":                             # (heads·Dh, D)
        return P("model", "data")
    if name in ("wg", "wu"):
        if len(shape) == 3:                      # MoE experts (E, D, F)
            return P("model", "data", None)
        return P("data", "model")                # dense (D, F)
    if name == "wd":
        if len(shape) == 3:                      # (E, F, D)
            return P("model", None, "data")
        return P("model", "data")                # dense (F, D)
    if name == "router":                         # (D, E) — small
        return P()
    if name == "in_proj":                        # mamba (D, big)
        return P("data", "model")
    if name == "out_proj":                       # mamba (d_inner, D)
        return P("model", "data")
    if name == "conv_w":                         # (K, conv_dim)
        return P(None, "model")
    if name == "conv_b":
        return P("model")
    if name in ("bq", "bk", "bv"):               # attention biases
        return P("model")
    if "shared" in joined and name in ("wg", "wu"):
        return P("data", "model")
    # norms, gates, A_log, dt_bias, D_skip, q_norm/k_norm ... replicate
    return P()


def _path_keys(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(f"{p.idx}")
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def param_specs(mesh, params_tree, profile: str = "2d") -> Any:
    """PartitionSpec tree matching ``params_tree`` (arrays or SDS)."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        keys = _path_keys(path)
        shape = tuple(leaf.shape)
        stacked = "blocks" in keys and len(shape) >= 1
        core_shape = shape[1:] if stacked else shape
        spec = _role_spec(keys, core_shape, profile)
        if stacked:
            spec = P(None, *spec)
        specs.append(fit_spec(mesh, shape, spec))
    return tdef.unflatten(specs)


def param_shardings(mesh, params_tree, profile: str = "2d") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(mesh, params_tree, profile))


# ----------------------------------------------------------------------
# batches / caches / optimizer state
# ----------------------------------------------------------------------

def batch_shardings(mesh, batch_tree) -> Any:
    ba = batch_axes(mesh)

    def one(leaf):
        spec = fit_spec(mesh, tuple(leaf.shape), P(ba))
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, batch_tree)


def cache_shardings(mesh, cache_tree) -> Any:
    """Decode caches: stacked (R, B, ...) leaves.

    KV k/v: (R, B, S, H_stored, Dh) → batch on DP axes, heads on model.
    Mamba conv (R, B, K-1, conv_dim) → conv_dim on model.
    Mamba ssm  (R, B, H, N, P) → heads on model.
    Cross media (R, B, M, H, Dh) → heads on model.
    length scalar → replicated.
    """
    ba = batch_axes(mesh)
    model_size = mesh.shape.get("model", 1)
    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        keys = _path_keys(path)
        shape = tuple(leaf.shape)
        name = keys[-1] if keys else ""
        if name == "length" or len(shape) == 0:
            spec = P()
        elif name in ("k", "v") and len(shape) == 5:
            # (R, B, S, H_stored, Dh): prefer head sharding; if the stored
            # heads don't divide the TP axis, shard the sequence instead
            # (flash-decoding: partial softmax stats all-reduce — XLA
            # derives it from the partial reductions).
            if shape[3] % model_size == 0:
                spec = P(None, ba, None, "model", None)
            else:
                spec = P(None, ba, "model", None, None)
        elif name == "conv":
            spec = P(None, ba, None, "model")
        elif name == "ssm":
            spec = P(None, ba, "model", None, None)
        else:
            spec = P(None, ba)
        out.append(NamedSharding(mesh, fit_spec(mesh, shape, spec)))
    return tdef.unflatten(out)


def opt_state_shardings(mesh, opt_tree, params_shardings: Any) -> Any:
    """m is param-shaped → reuse the param shardings. v likewise, except
    factored (vr/vc dict) leaves, which are small → replicated. count
    replicated."""
    out = {}
    is_v_leaf = lambda x: isinstance(x, dict) and "vr" in x
    for key, sub in opt_tree.items():
        if key == "m":
            out[key] = params_shardings
        elif key == "v":
            flat_v, vdef = jax.tree_util.tree_flatten(sub, is_leaf=is_v_leaf)
            flat_ps = jax.tree_util.tree_leaves(params_shardings)
            leaves = []
            for v, ps in zip(flat_v, flat_ps):
                if is_v_leaf(v):
                    leaves.append(dict(vr=replicated(mesh),
                                       vc=replicated(mesh)))
                else:
                    leaves.append(ps)
            out[key] = jax.tree_util.tree_unflatten(vdef, leaves)
        else:
            out[key] = jax.tree.map(lambda _: replicated(mesh), sub)
    return out
