"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each
cell we build the jitted step (train / prefill / decode) over
ShapeDtypeStructs (no allocation), ``.lower().compile()`` against the
production mesh, and record ``memory_analysis`` / ``cost_analysis`` /
the collective schedule parsed from the compiled HLO into
``artifacts/dryrun/<cell>.json`` — the §Roofline inputs.

Run:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh multi
"""

# The dry-run (and ONLY the dry-run) needs 512 placeholder devices —
# before ANY other import, since jax locks the device count on first init.
import os  # noqa: E402

if not os.environ.get("REPRO_DRYRUN_NO_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro import configs                     # noqa: E402
from repro.models import model as model_lib   # noqa: E402
from repro.models import stack as stack_lib   # noqa: E402
from repro.optim import AdamWConfig, adamw_init, adamw_update  # noqa: E402
from repro.launch import shardings as shd     # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_steal_table  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

# Desired gradient-accumulation microbatches per arch (train_4k): bounds
# the scan-carried activation memory (per-device bytes ≈ R·rows·S·D·2 /
# microbatches). Clamped to the DP shard count at mesh time.
# archs whose optimizer runs in factored (Adafactor-v + bf16-m) mode to
# fit 16 GB/chip — production practice for ≥100B params on v5e.
FACTORED_OPT = {"jamba-1.5-large-398b", "llama-3.2-vision-90b",
                "llama4-scout-17b-a16e", "command-r-35b"}

MICRO_WANTED = {
    "llama-3.2-vision-90b": 16,
    "command-r-35b": 16,
    "jamba-1.5-large-398b": 16,
    "llama4-scout-17b-a16e": 8,
    "qwen3-14b": 16,
    "qwen2.5-3b": 4,
    "stablelm-1.6b": 4,
    "granite-moe-1b-a400m": 4,
    "hubert-xlarge": 4,
    "mamba2-1.3b": 4,
}


def cell_id(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}"


def num_microbatches(arch: str, shape_spec, mesh) -> int:
    if shape_spec.kind != "train":
        return 1
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    return max(1, min(MICRO_WANTED.get(arch, 4),
                      shape_spec.global_batch // dp))


def adapt_config(cfg, shape_spec, mesh, micro: int = 1):
    """Mesh-dependent config adjustments (the launcher's job).

    * kv_repeat (GQA TP replication) only when the replicated head count
      both divides the query heads (attention math) and is divisible by
      the model axis (sharding math): e.g. command-r 64H/8kv → ×2 = 16
      stored; qwen3 40H/8kv can't (16 ∤ 40) → its KV activations/cache
      fall back to sequence-sharding (flash-decoding style).
    * activation sharding constraints are derived here with divisibility
      fit against the cell's concrete shapes.
    """
    from repro.launch import shardings as _shd

    model_axis = mesh.shape["model"]
    ba = _shd.batch_axes(mesh)
    updates: dict = {}
    kv = cfg.num_kv_heads
    rep = 1
    if (cfg.num_heads > 1 and kv < model_axis and model_axis % kv == 0):
        r = model_axis // kv
        if cfg.num_heads % (kv * r) == 0:
            rep = r
            updates["kv_repeat"] = rep
    stored = kv * rep

    rows = shape_spec.global_batch
    if shape_spec.kind == "train":
        rows = max(1, shape_spec.global_batch // micro)
    S = 1 if shape_spec.kind == "decode" else shape_spec.seq_len
    Skv = shape_spec.seq_len if shape_spec.kind == "decode" else S

    def fit(shape, *spec):
        p = _shd.fit_spec(mesh, shape, _shd.P(*spec))
        entries = tuple(p) + (None,) * (len(shape) - len(tuple(p)))
        return entries if any(e is not None for e in entries) else None

    if cfg.num_heads > 1:
        updates["attn_q_spec"] = fit(
            (rows, S, cfg.num_heads, cfg.head_dim), ba, None, "model")
        if stored % model_axis == 0:
            updates["attn_kv_spec"] = fit(
                (rows, Skv, stored, cfg.head_dim), ba, None, "model")
        else:
            # sequence-sharded KV (flash-decoding / context parallel)
            updates["attn_kv_spec"] = fit(
                (rows, Skv, stored, cfg.head_dim), ba, "model", None)
    if cfg.ssm_state:
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        updates["ssm_act_spec"] = fit(
            (rows, S, H, cfg.ssm_head_dim), ba, None, "model")
    if cfg.moe_num_experts:
        T = rows * S
        G = min(cfg.moe_group, T)
        updates["moe_group_spec"] = fit((T // G, G, cfg.d_model),
                                        ba, None, None)
        cap = int(np.ceil(G * cfg.moe_top_k * cfg.capacity_factor
                          / cfg.moe_num_experts))
        ff = cfg.moe_d_ff or cfg.d_ff
        # groups ride the DP axes, experts the model axis
        updates["moe_xin_spec"] = fit(
            (T // G, cfg.moe_num_experts, cap, cfg.d_model),
            ba, "model", None, None)
        updates["moe_h_spec"] = fit(
            (T // G, cfg.moe_num_experts, cap, ff),
            ba, "model", None, None)
    if shape_spec.kind == "train":
        updates["remat"] = "full"
        if len(cfg.pattern) > 1:
            updates["serialize_slot_gathers"] = True
    return dataclasses.replace(cfg, **updates)


# ----------------------------------------------------------------------
# step builders (abstract inputs)
# ----------------------------------------------------------------------

def batch_struct(cfg, shape_spec):
    B, S = shape_spec.global_batch, shape_spec.seq_len
    b = {"labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.embeds_input:
        b["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                           cfg.param_dtype)
    else:
        b["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.num_media_tokens:
        b["media"] = jax.ShapeDtypeStruct(
            (B, cfg.num_media_tokens, cfg.d_model), cfg.param_dtype)
    return b


def input_specs(arch: str, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = configs.get(arch)
    spec = configs.SHAPES[shape]
    if spec.kind == "train":
        return batch_struct(cfg, spec)
    if spec.kind == "prefill":
        return batch_struct(cfg, spec)
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((spec.global_batch, 1),
                                           jnp.int32)}


def make_train_step(cfg, opt_cfg: AdamWConfig, num_micro: int,
                    steal_table):
    acc_dtype = "bfloat16" if opt_cfg.factored else None

    def train_step(params, opt_state, batch):
        from repro.optim import accumulate_gradients
        loss, grads, _ = accumulate_gradients(
            lambda p, b: model_lib.train_loss(p, cfg, b,
                                              steal_table=steal_table),
            params, batch, num_micro, acc_dtype=acc_dtype)
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        return params, opt_state, loss, metrics["grad_norm"]
    return train_step


def make_prefill_step(cfg, steal_table):
    def prefill_step(params, batch):
        if cfg.is_encoder:
            logits, _ = model_lib.forward(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), media=batch.get("media"),
                steal_table=steal_table)
            return logits[:, -1]
        logits, caches = model_lib.prefill(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), media=batch.get("media"),
            steal_table=steal_table)
        return logits, caches["length"]
    return prefill_step


def make_decode_step(cfg, steal_table):
    def decode_step(params, caches, tokens):
        logits, caches = model_lib.decode_step(params, cfg, caches, tokens,
                                               steal_table=steal_table)
        return logits, caches
    return decode_step


def abstract_caches(cfg, batch: int, max_len: int):
    sds = jax.eval_shape(
        lambda: stack_lib.init_caches(cfg, batch, max_len, cfg.param_dtype))
    # decode starts with a full cache: length is a traced scalar anyway
    return sds


# ----------------------------------------------------------------------
# collective parsing (HLO text → bytes moved per collective kind)
# ----------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\([^)]*\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.I)
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|f64|pred|s64|u64)"
                       r"\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}|replica_groups=\[")

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def _group_size(line: str) -> tuple[int, bool]:
    """(collective group size, crosses-pod?) from an HLO line."""
    gm = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if gm:
        ids = [int(x) for x in gm.group(1).split(",") if x.strip()]
        cross = bool(ids) and (max(ids) // 256) != (min(ids) // 256)
        return max(len(ids), 1), cross
    # iota form: replica_groups=[G,S]<=[...] (optionally T(perm)):
    # G groups of size S; contiguous groups cross the pod boundary only
    # when S > 256, transposed ones stride across pods whenever the
    # total spans both pods.
    gm = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\dx,]+)\]"
                   r"(T\([\d,]+\))?", line)
    if gm:
        g, su = int(gm.group(1)), int(gm.group(2))
        total = g * su
        if gm.group(4):
            cross = total > 256 and su > 1
        else:
            cross = su > 256
        return su, cross
    return 1, False


def parse_collectives(hlo: str) -> dict:
    """Collective schedule from the compiled (per-partition) module.

    Records result-shape bytes, estimated per-device wire bytes (ring
    algorithms: all-gather ≈ R·(g−1)/g, all-reduce ≈ 2·R·(g−1)/g,
    reduce-scatter ≈ R·(g−1) with R the scattered result, all-to-all /
    permute ≈ R), and the share crossing the pod boundary (DCI).
    """
    out: dict[str, dict] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3).lower()
        result_part = line.split("=", 1)[1] if "=" in line else line
        head = result_part.split(kind, 1)[0]
        nbytes = 0
        for dm in _SHAPE_RE.finditer(head):
            dt, dims = dm.group(1), dm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _BYTES.get(dt, 4)
        g, cross_pod = _group_size(line)
        if kind == "all-reduce":
            wire = 2 * nbytes * max(g - 1, 0) // max(g, 1)
        elif kind == "all-gather":
            wire = nbytes * max(g - 1, 0) // max(g, 1)
        elif kind == "reduce-scatter":
            wire = nbytes * max(g - 1, 1)
        else:  # all-to-all, collective-permute
            wire = nbytes
        rec = out.setdefault(kind, dict(count=0, bytes=0, wire_bytes=0,
                                        cross_pod_bytes=0,
                                        cross_pod_wire_bytes=0))
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["wire_bytes"] += wire
        if cross_pod:
            rec["cross_pod_bytes"] += nbytes
            rec["cross_pod_wire_bytes"] += wire
    return out


# ----------------------------------------------------------------------
# cell runner
# ----------------------------------------------------------------------

def run_cell(arch: str, shape: str, mesh_kind: str,
             skip_existing: bool = True, verbose: bool = True,
             variant: str | None = None,
             cfg_overrides: dict | None = None,
             micro_override: int | None = None,
             opt_overrides: dict | None = None,
             out_dir: str | None = None) -> dict:
    """Lower+compile one cell. ``variant``/overrides support the §Perf
    hillclimb loop: config fields are replaced *after* mesh adaptation,
    results land in ``out_dir`` (default: the dry-run artifact tree)."""
    art = out_dir or ARTIFACTS
    os.makedirs(art, exist_ok=True)
    name = cell_id(arch, shape, mesh_kind) + (f"__{variant}" if variant
                                              else "")
    path = os.path.join(art, name + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg0 = configs.get(arch)
    spec = configs.SHAPES[shape]
    if shape not in cfg0.shapes():
        rec = dict(arch=arch, shape=shape, mesh=mesh_kind, status="skipped",
                   reason=cfg0.skipped_shapes().get(shape, "n/a"))
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_micro = micro_override or num_microbatches(arch, spec, mesh)
    cfg = adapt_config(cfg0, spec, mesh, micro=n_micro)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    steal = None
    if cfg.moe_num_experts:
        steal = mesh_steal_table(mesh, cfg.moe_num_experts,
                                 cfg.moe_steal_policy)

    params_sds = model_lib.abstract_params(cfg)
    p_shard = shd.param_shardings(mesh, params_sds, cfg.sharding_profile)

    try:
        with mesh:
            if spec.kind == "train":
                opt_cfg = AdamWConfig(
                    factored=arch in FACTORED_OPT,
                    m_dtype="bfloat16" if arch in FACTORED_OPT
                    else "float32")
                if opt_overrides:
                    opt_cfg = dataclasses.replace(opt_cfg, **opt_overrides)
                opt_sds = jax.eval_shape(
                    lambda p: adamw_init(p, opt_cfg), params_sds)
                o_shard = shd.opt_state_shardings(mesh, opt_sds, p_shard)
                batch_sds = batch_struct(cfg, spec)
                b_shard = shd.batch_shardings(mesh, batch_sds)
                step = make_train_step(cfg, opt_cfg, n_micro, steal)
                lowered = jax.jit(
                    step,
                    in_shardings=(p_shard, o_shard, b_shard),
                    out_shardings=(p_shard, o_shard, None, None),
                    donate_argnums=(0, 1),
                ).lower(params_sds, opt_sds, batch_sds)
            elif spec.kind == "prefill":
                batch_sds = batch_struct(cfg, spec)
                b_shard = shd.batch_shardings(mesh, batch_sds)
                step = make_prefill_step(cfg, steal)
                lowered = jax.jit(
                    step, in_shardings=(p_shard, b_shard),
                ).lower(params_sds, batch_sds)
            else:  # decode
                caches_sds = abstract_caches(cfg, spec.global_batch,
                                             spec.seq_len)
                c_shard = shd.cache_shardings(mesh, caches_sds)
                tok_sds = jax.ShapeDtypeStruct((spec.global_batch, 1),
                                               jnp.int32)
                step = make_decode_step(cfg, steal)
                lowered = jax.jit(
                    step,
                    in_shardings=(p_shard, c_shard, None),
                    out_shardings=(None, c_shard),
                    donate_argnums=(1,),
                ).lower(params_sds, caches_sds, tok_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:  # record the failure for triage, then re-raise
        rec = dict(arch=arch, shape=shape, mesh=mesh_kind, status="error",
                   error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        raise

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    rec = dict(
        arch=arch, shape=shape, mesh=mesh_kind, status="ok",
        variant=variant,
        cfg_overrides={k: (list(v) if isinstance(v, tuple) else v)
                       for k, v in (cfg_overrides or {}).items()
                       if not k.endswith("_spec")},
        opt=({**dict(factored=arch in FACTORED_OPT),
              **(opt_overrides or {})} if spec.kind == "train" else None),
        grad_acc_dtype=("bfloat16" if (arch in FACTORED_OPT or
                                       (opt_overrides or {}).get("factored"))
                        else "float32") if spec.kind == "train" else None,
        mesh_shape=list(np.asarray(mesh.devices).shape),
        num_devices=int(np.asarray(mesh.devices).size),
        kind=spec.kind,
        microbatches=n_micro,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=getattr(ma, "argument_size_in_bytes", None),
            output_bytes=getattr(ma, "output_size_in_bytes", None),
            temp_bytes=getattr(ma, "temp_size_in_bytes", None),
            generated_code_bytes=getattr(ma, "generated_code_size_in_bytes",
                                         None),
        ),
        cost=dict(
            flops_per_device=ca.get("flops"),
            transcendentals=ca.get("transcendentals"),
            bytes_accessed_per_device=ca.get("bytes accessed"),
        ),
        collectives=colls,
        param_count=model_lib.param_count(cfg),
        active_param_count=model_lib.active_param_count(cfg),
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        mm = (rec["memory"]["argument_bytes"] or 0) + \
            (rec["memory"]["temp_bytes"] or 0)
        print(f"[dryrun] {name:56s} ok "
              f"mem/dev={mm/2**30:6.2f}GiB "
              f"flops/dev={rec['cost']['flops_per_device'] or 0:.3e} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(configs.ARCHS)
    shapes = [args.shape] if args.shape else list(configs.SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    failures = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                try:
                    run_cell(a, s, m, skip_existing=not args.force)
                except Exception as e:
                    failures.append((a, s, m, str(e)))
                    print(f"[dryrun] FAIL {a} {s} {m}: {e}")
    if failures:
        print(f"\n{len(failures)} cells failed")
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled")


if __name__ == "__main__":
    main()
