"""Batched serving driver: prefill a prompt batch, decode with KV/SSM
caches, report latency/throughput.

The decode loop is the production shape (jit'd single-token step over a
static-capacity cache, donated buffers); batch composition is static per
run (continuous batching would swap finished rows — the cache layout
already supports per-row lengths via the shared ``length`` counter).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --reduced --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as model_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, P), 1, cfg.vocab_size)
    media = None
    if cfg.num_media_tokens:
        media = jax.random.normal(
            key, (B, cfg.num_media_tokens, cfg.d_model), cfg.param_dtype)

    max_len = P + args.gen

    @jax.jit
    def prefill_fn(params, tokens, media):
        return model_lib.prefill(params, cfg, tokens=tokens, media=media,
                                 max_len=max_len)

    @jax.jit
    def decode_fn(params, caches, tok):
        logits, caches = model_lib.decode_step(params, cfg, caches, tok)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], caches

    t0 = time.time()
    logits, caches = prefill_fn(params, prompts, media)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, caches = decode_fn(params, caches, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    per_tok = t_decode / max(args.gen - 1, 1)
    print(f"[serve] {cfg.name}: batch={B} prompt={P} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:8.1f} ms "
          f"({B*P/t_prefill:9.0f} tok/s)")
    print(f"[serve] decode  {per_tok*1e3:8.2f} ms/tok "
          f"({B/max(per_tok,1e-9):9.0f} tok/s)")
    print(f"[serve] sample row 0: {gen[0][:16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
