"""End-to-end training driver.

Production-shaped loop: sharded params/opt-state, grad accumulation,
checkpoint-every-k with async writes + exact resume (stateless data
pipeline), straggler monitoring hooks, optional int8-compressed cross-pod
gradients, and the paper's topology-aware placement (mesh ordering +
MoE steal tables).

Runs anywhere: on this CPU container use ``--reduced`` (same code path,
small model). Example (quickstart uses the same entry):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --steps 50 --global-batch 8 --seq-len 128
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core import topology as topo_mod
from repro.core.routing import expert_steal_table
from repro.data import PipelineConfig, Prefetcher, TokenPipeline
from repro.launch import shardings as shd
from repro.models import model as model_lib
from repro.optim import (AdamWConfig, accumulate_gradients, adamw_init,
                         adamw_update, compressed_gradients)
from repro.runtime import HeartbeatMonitor


def build_train_step(cfg, opt_cfg, n_micro, steal_table, compress=False):
    def step_fn(params, opt_state, comp_state, batch):
        loss, grads, metrics = accumulate_gradients(
            lambda p, b: model_lib.train_loss(p, cfg, b,
                                              steal_table=steal_table),
            params, batch, n_micro)
        if compress:
            grads, comp_state = compressed_gradients(grads, comp_state)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        return params, opt_state, comp_state, loss, om["grad_norm"]
    return step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="same-family small config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 + error feedback (cross-pod wire format)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat="none" if args.reduced else "full")

    # paper technique: steal table from the (modeled) topology
    steal = None
    if cfg.moe_num_experts:
        n_dev = max(len(jax.devices()), cfg.moe_num_experts)
        topo = topo_mod.tpu_pod_2d(1, n_dev) if n_dev > 1 \
            else topo_mod.uma(cfg.moe_num_experts)
        owners = np.arange(cfg.moe_num_experts) % topo.num_cores
        steal = expert_steal_table(topo, owners, cfg.moe_steal_policy)

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps)
    opt_state = adamw_init(params, opt_cfg)

    pipe = TokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed,
        embeds_dim=cfg.d_model if cfg.embeds_input else 0,
        media_tokens=cfg.num_media_tokens, d_model=cfg.d_model))

    start_step = 0
    mgr = None
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir, keep_last=3)
        got = mgr.restore_latest({"params": params, "opt": opt_state})
        if got[0] is not None:
            start_step, tree = got
            params, opt_state = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(build_train_step(cfg, opt_cfg, args.microbatches,
                                       steal, args.compress_grads))
    comp_state = None
    monitor = HeartbeatMonitor(num_hosts=1)
    it = Prefetcher(pipe.iter_from(start_step))

    t_start = time.time()
    tokens_done = 0
    loss = float("nan")
    for step in range(start_step, args.steps):
        batch = next(it)
        t0 = time.time()
        params, opt_state, comp_state, loss, gnorm = step_fn(
            params, opt_state, comp_state, batch)
        loss = jax.block_until_ready(loss)
        dt = time.time() - t0
        monitor.beat(0, dt)
        tokens_done += args.global_batch * args.seq_len
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(loss):8.4f} "
                  f"gnorm {float(gnorm):7.3f} {dt*1e3:7.1f} ms/step "
                  f"{tokens_done/(time.time()-t_start):9.0f} tok/s")
        if mgr and (step + 1) % args.checkpoint_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save_sync(args.steps, {"params": params, "opt": opt_state})
        mgr.wait()
    it.close()
    print(f"[train] done: final loss {float(loss):.4f}")
    return float(loss)


if __name__ == "__main__":
    main()
