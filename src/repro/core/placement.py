"""Topology-aware placement — the paper's §IV applied at two levels.

1. **Faithful level** (used by the simulator/benchmarks): first-touch
   spill sets — where the OS puts large master-allocated arrays — and the
   thread→core binding from :func:`repro.core.priority.allocate_threads`.

2. **TPU adaptation** (used by ``launch/mesh.py``): assignment of logical
   mesh coordinates to physical devices. The paper binds OpenMP threads to
   cores so communicating threads are few hops apart; we bind *logical
   mesh positions* to *chips* so that the heavy-collective axis ("model")
   maps onto minimal-hop rings and the master/coordinator sits at the
   topology centroid (first-touch analogue: initialization, RNG seeding
   and checkpoint leadership happen there).

All functions are pure and run at launch time only.
"""

from __future__ import annotations

import numpy as np

from .priority import PriorityResult, allocate_threads, priorities
from .topology import Topology

__all__ = [
    "first_touch_spill",
    "master_node",
    "device_order_baseline",
    "device_order_priority",
    "layout_cost",
]


def first_touch_spill(topo: Topology, start_node: int, num_nodes: int,
                      pr: PriorityResult | None = None) -> list[int]:
    """Nodes receiving pages of a large allocation first-touched on
    ``start_node``; the OS falls back to the *closest* nodes as each
    fills (paper §V.B). Ties by priority when given, else by node id —
    baseline Linux walks node ids."""
    d = topo.node_distance[start_node].astype(np.float64)
    if pr is not None:
        node_pr = np.zeros(topo.num_nodes)
        for n in range(topo.num_nodes):
            cs = topo.cores_on_node(n)
            node_pr[n] = max(pr.total[cs]) if cs else -np.inf
        order = np.lexsort((-node_pr, d))
    else:
        order = np.lexsort((np.arange(topo.num_nodes), d))
    return [int(n) for n in order[:num_nodes]]


def master_node(topo: Topology, seed: int = 0) -> int:
    """Node of the master thread under the paper's allocation."""
    master_core = allocate_threads(topo, 1, seed=seed)[0]
    return int(topo.core_node[master_core])


# ----------------------------------------------------------------------
# TPU adaptation: logical mesh coordinate → physical device ordering
# ----------------------------------------------------------------------

def device_order_baseline(topo: Topology) -> np.ndarray:
    """Default JAX behavior: devices in enumeration order."""
    return np.arange(topo.num_cores, dtype=np.int64)


def device_order_priority(topo: Topology, mesh_shape: tuple[int, ...],
                          major_axis_last: bool = True,
                          seed: int = 0) -> np.ndarray:
    """Order physical devices so that reshaping to ``mesh_shape`` puts
    consecutive last-axis (highest-traffic, e.g. "model") positions on
    minimal-hop neighbors.

    The paper's worker-placement loop, applied *per ring*: within each
    window of ``mesh_shape[-1]`` logical positions (one "model" ring) we
    seed at the best unassigned device and repeatedly take the unassigned
    device closest to the previous one (ties by priority, then id) — the
    paper's "place new workers as close as possible" rule. Each following
    ring seeds at the unassigned device closest to the previous ring's
    seed, so the slowly-varying ("data"/"pod") axes stay compact too.

    Returns a permutation ``perm`` with ``perm[i]`` = physical device id
    of logical position ``i`` (row-major over ``mesh_shape``).
    """
    n = int(np.prod(mesh_shape))
    if n != topo.num_cores:
        raise ValueError(f"mesh {mesh_shape} needs {n} devices, "
                         f"topology has {topo.num_cores}")
    ring = int(mesh_shape[-1]) if len(mesh_shape) > 1 else n
    pr = priorities(topo)
    total = pr.total
    dist = topo.core_distance_matrix()
    rng = np.random.RandomState(seed)

    unassigned = np.ones(n, bool)

    def pick(dvec):
        d = dvec.astype(np.float64).copy()
        d[~unassigned] = np.inf
        cand = np.nonzero(d == d.min())[0]
        pbest = total[cand].max()
        cand = cand[total[cand] == pbest]
        return int(cand[0])

    order: list[int] = []
    prev_seed = None
    for _ in range(n // ring):
        if prev_seed is None:
            best = total[unassigned].max()
            ties = np.nonzero((total == best) & unassigned)[0]
            cur = int(ties[rng.randint(ties.size)])
        else:
            cur = pick(dist[prev_seed])
        prev_seed = cur
        order.append(cur)
        unassigned[cur] = False
        for _ in range(ring - 1):
            cur = pick(dist[cur])
            order.append(cur)
            unassigned[cur] = False
    return np.asarray(order, np.int64)


def layout_cost(topo: Topology, perm: np.ndarray,
                mesh_shape: tuple[int, ...],
                axis_traffic: tuple[float, ...] | None = None) -> float:
    """Hop-weighted collective cost of a device layout.

    For each mesh axis, collectives (all-reduce / all-gather rings) run
    between devices adjacent along that axis; cost is the mean hop count
    of those ring edges, weighted by relative axis traffic (default: last
    axis carries 8× — TP/EP collectives dominate gradient sync per step).
    Used by benchmarks and by §Perf to compare baseline vs priority
    layouts.
    """
    shape = tuple(mesh_shape)
    if axis_traffic is None:
        axis_traffic = tuple([1.0] * (len(shape) - 1) + [8.0])
    grid = np.asarray(perm).reshape(shape)
    dist = topo.core_distance_matrix()
    total, weight = 0.0, 0.0
    for ax, w in enumerate(axis_traffic):
        if shape[ax] == 1:
            continue
        a = np.moveaxis(grid, ax, 0)
        nxt = np.roll(a, -1, axis=0)  # ring neighbor along this axis
        hops = dist[a.ravel(), nxt.ravel()].astype(np.float64)
        total += w * hops.mean()
        weight += w
    return total / max(weight, 1e-12)
