"""The paper's priority-based thread→core allocation (§IV, Figs 2–4).

Faithful reproduction of the two-level priority computation:

  Level 1 (Fig 2):  V1(c) = Σ_i α_i · N_i(c)
      α_i is a weight per hop distance i with α_i > α_{i+1} (and α beyond
      max-numa-distance = 0); N_i(c) is the number of cores at i hops
      from core c. A first "node size" term is granted before V1: cores on
      the socket with the most cores attached to one NUMA node get the
      highest base priority (paper: "assign high priority to cores of the
      socket/chip having the largest number of cores attached to the same
      NUMA memory node").

  Level 2 (Fig 3):  V2(c) = Σ_i Σ_j α_i · P_ij
      folds in the previously computed priorities P of cores at each hop —
      useful when several hop distances exist, the machine is
      heterogeneous, or some cores are already occupied.

  Final priority = base + V1 + V2 (paper Fig 4 accumulates levels in
  place; we keep the levels separable for analysis/tests).

Master/worker placement (paper §IV, end):
  * master binds to the max-priority core (ties → random, seeded);
  * each next worker binds as close as possible to the master's core,
    ties by higher priority, remaining ties random.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .topology import Topology, lazy_cache

__all__ = [
    "default_weights",
    "priorities",
    "PriorityResult",
    "allocate_threads",
]


def default_weights(max_distance: int) -> np.ndarray:
    """α_i for i in [0, max_distance], strictly decreasing, α_{max+1}=0.

    The paper leaves the coefficients free ("a coefficient number
    decreasing with growing number of hops"); we use a geometric decay
    α_i = 2^{-i} which satisfies α_i > α_{i+1} > 0 over the support.
    """
    return 0.5 ** np.arange(max_distance + 1, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class PriorityResult:
    base: np.ndarray    # node-size term, per core
    v1: np.ndarray      # Fig 2 term, per core
    v2: np.ndarray      # Fig 3 term, per core
    total: np.ndarray   # base + v1 + v2

    def ranking(self) -> np.ndarray:
        """Core ids sorted by descending priority (stable: id asc ties)."""
        # argsort is ascending; sort by (-total, id) for deterministic order.
        order = np.lexsort((np.arange(self.total.size), -self.total))
        return order


def _memo_key(weights, available, *rest):
    """Hashable cache key for the per-topology memo tables below."""
    wk = None if weights is None else tuple(np.asarray(weights,
                                                      np.float64).tolist())
    ak = None if available is None else tuple(int(c) for c in available)
    return (wk, ak) + rest


def priorities(topo: Topology,
               weights: np.ndarray | None = None,
               available: Sequence[int] | None = None,
               occupied_penalty: float = 0.0) -> PriorityResult:
    """Compute per-core priorities on ``topo`` per the paper's algorithm.

    Memoized on the (immutable) topology per (weights, available,
    occupied_penalty) — like ``_root_dist_cache`` — because benchmark
    sweeps recompute the identical result hundreds of times per grid.
    The returned arrays are shared; treat them as read-only.

    Args:
      topo: the machine.
      weights: α_i per hop distance; defaults to ``default_weights``.
      available: optional subset of core ids considered free. Cores outside
        the subset contribute nothing to N_i / P_ij (paper: "in case some
        cores have already been allocated for other work") and get -inf
        total so they are never selected.
      occupied_penalty: subtractive weight for occupied cores (0 = simply
        excluded, matching the strict reading).
    """
    cache = lazy_cache(topo, "_priority_cache")
    key = _memo_key(weights, available, float(occupied_penalty))
    hit = cache.get(key)
    if hit is not None:
        return hit
    n = topo.num_cores
    dist = topo.core_distance_matrix()
    maxd = topo.max_distance()
    if weights is None:
        weights = default_weights(maxd)
    weights = np.asarray(weights, np.float64)
    if weights.size < maxd + 1:
        raise ValueError(f"need weights for hop 0..{maxd}")
    if np.any(np.diff(weights) >= 0):
        raise ValueError("weights must be strictly decreasing (α_i > α_{i+1})")

    free = np.ones(n, bool)
    if available is not None:
        free[:] = False
        free[list(available)] = True

    # --- base term: size of the core's NUMA node (socket with the most
    # cores attached to the same memory node → highest base priority).
    node_sizes = np.bincount(topo.core_node, weights=free.astype(np.float64),
                             minlength=topo.num_nodes)
    base = node_sizes[topo.core_node]
    # Paper: "If all nodes have equal number of cores ... same priority".
    if np.all(node_sizes[np.unique(topo.core_node)] ==
              node_sizes[np.unique(topo.core_node)][0]):
        base = np.zeros(n)

    # --- V1 (Fig 2): Σ_i α_i N_i over *other*, free cores.
    w_of_pair = weights[dist]                      # (n, n) α_{dist(a,b)}
    contrib = w_of_pair * free[None, :]
    np.fill_diagonal(contrib, 0.0)                 # N_i counts other cores
    v1 = contrib.sum(axis=1)

    p_old = base + v1

    # --- V2 (Fig 3): Σ_i Σ_j α_i P_ij with P the already-found priorities.
    pc = np.where(free, p_old, occupied_penalty)
    contrib2 = w_of_pair * pc[None, :]
    np.fill_diagonal(contrib2, 0.0)
    v2 = contrib2.sum(axis=1)

    total = p_old + v2
    total = np.where(free, total, -np.inf)
    result = PriorityResult(base=base, v1=v1, v2=v2, total=total)
    for arr in (result.base, result.v1, result.v2, result.total):
        arr.flags.writeable = False     # the memoized arrays are shared
    cache[key] = result
    return result


def allocate_threads(topo: Topology,
                     num_threads: int,
                     weights: np.ndarray | None = None,
                     available: Sequence[int] | None = None,
                     seed: int = 0) -> list[int]:
    """Bind ``num_threads`` threads to cores per the paper's policy.

    Returns core ids, index = thread id; thread 0 is the master.

    Policy (paper §IV): master → highest-priority core (random among
    ties); worker k → unbound core closest to the master's core, ties by
    higher priority, then random.

    Memoized on the topology per (num_threads, weights, available,
    seed): the O(n²) allocation is identical across the hundreds of
    sweep configs that share a thread count, so it is computed once.
    """
    cache = lazy_cache(topo, "_alloc_cache")
    key = _memo_key(weights, available, int(num_threads), int(seed))
    hit = cache.get(key)
    if hit is not None:
        return list(hit)
    pr = priorities(topo, weights=weights, available=available)
    rng = np.random.RandomState(seed)
    total = pr.total
    n = topo.num_cores
    if num_threads > np.isfinite(total).sum():
        raise ValueError("more threads than available cores")

    dist = topo.core_distance_matrix()
    bound: list[int] = []
    is_free = np.isfinite(total)

    # master
    best = total.max()
    ties = np.nonzero((total == best) & is_free)[0]
    master = int(ties[rng.randint(ties.size)])
    bound.append(master)
    is_free[master] = False

    for _ in range(1, num_threads):
        d = dist[master].astype(np.float64)
        d[~is_free] = np.inf
        dmin = d.min()
        cand = np.nonzero(d == dmin)[0]
        # ties by higher priority
        pbest = total[cand].max()
        cand = cand[total[cand] == pbest]
        pick = int(cand[rng.randint(cand.size)])
        bound.append(pick)
        is_free[pick] = False
    cache[key] = tuple(bound)
    return bound
