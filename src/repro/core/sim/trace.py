"""Event-trace capture: structure-of-arrays record of one simulation.

``SimParams(trace=True)`` makes both engines record, per run:

* **exec events** — one per *committed* task execution (the commit point
  after fault preemption checks): task id, executing thread / core /
  NUMA node, queue depth sampled at commit, and the ``[start, end)``
  interval on the simulated clock. Aborted (re-executed) attempts are
  not exec events; join continuations fold into the task's accounting
  and emit none either.
* **steal events** — one per successful steal: time, thief and victim
  threads, stolen task id, and the hop distance between the thieving
  and victim cores' nodes.
* **migration events** — one per OS thread migration: time, thread,
  from-core, to-core.

The layout is structure-of-arrays (one flat numpy array per column) so
paper-scale traces (millions of events) stay cache-friendly and
zero-copy between the C kernel and numpy: the kernel grows flat C
arrays geometrically and hands the final pointers back wrapped as numpy
arrays (an owner object frees them when the last view dies). The
Python engine appends into numpy arrays with the same geometric growth.

Tracing is purely observational — a traced run's :class:`~.runtime.
SimResult` metrics are bit-identical to the untraced run (pinned by
``tests/test_trace.py``), and both engines produce identical traces
event-for-event.

:meth:`TraceBuffer.save_npz` / :meth:`TraceBuffer.load_npz` round-trip
a trace through a single ``.npz`` file — the sidecar format the result
store uses to spill traces next to its journal (see ``store.py``).
"""

from __future__ import annotations

import json

import numpy as np

__all__ = ["TraceBuffer", "plan_capacity"]

# (column, dtype) per event family; the single source of truth for the
# npz schema, pickling, and parity comparison.
EXEC_COLS = (("ex_task", np.int64), ("ex_thread", np.int64),
             ("ex_core", np.int64), ("ex_node", np.int64),
             ("ex_qlen", np.int64), ("ex_start", np.float64),
             ("ex_end", np.float64))
STEAL_COLS = (("st_time", np.float64), ("st_thief", np.int64),
              ("st_victim", np.int64), ("st_task", np.int64),
              ("st_dist", np.int64))
MIG_COLS = (("mg_time", np.float64), ("mg_thread", np.int64),
            ("mg_from", np.int64), ("mg_to", np.int64))
ALL_COLS = EXEC_COLS + STEAL_COLS + MIG_COLS


def plan_capacity(n_tasks: int) -> "tuple[int, int, int]":
    """Initial (exec, steal, migration) capacities for an ``n_tasks`` run.

    Every task commits exactly one exec event on a fault-free run, so
    the exec family is allocated exactly once up front; steals and
    migrations are workload-dependent, so they start small and grow
    geometrically. Both engines use this plan so growth behavior (and
    therefore allocation cost) matches.
    """
    n = max(int(n_tasks), 1)
    return n, max(n // 8, 64), 64


class TraceBuffer:
    """One run's event trace (see module docstring for semantics).

    Column arrays are exposed as attributes (``ex_task``, ``st_time``,
    ...), trimmed to the recorded event counts ``n_exec`` / ``n_steal``
    / ``n_mig``. ``meta`` carries run identity (scheduler, seed, engine,
    threads, topology sizes) for the analysis layer.
    """

    def __init__(self, n_tasks: int = 0, meta: "dict | None" = None):
        ex_cap, st_cap, mg_cap = plan_capacity(n_tasks)
        for name, dt in EXEC_COLS:
            setattr(self, name, np.empty(ex_cap, dtype=dt))
        for name, dt in STEAL_COLS:
            setattr(self, name, np.empty(st_cap, dtype=dt))
        for name, dt in MIG_COLS:
            setattr(self, name, np.empty(mg_cap, dtype=dt))
        self.n_exec = 0
        self.n_steal = 0
        self.n_mig = 0
        self.meta: dict = dict(meta or {})
        self._owner = None   # keeps C-allocated storage alive (see _csim)
        self._final = False

    # ---- recording (python engine) ----

    def _grow(self, cols) -> None:
        for name, _ in cols:
            a = getattr(self, name)
            b = np.empty(max(len(a) * 2, 64), dtype=a.dtype)
            b[:len(a)] = a
            setattr(self, name, b)

    def add_exec(self, task: int, thread: int, core: int, node: int,
                 qlen: int, start: float, end: float) -> None:
        i = self.n_exec
        if i >= len(self.ex_task):
            self._grow(EXEC_COLS)
        self.ex_task[i] = task
        self.ex_thread[i] = thread
        self.ex_core[i] = core
        self.ex_node[i] = node
        self.ex_qlen[i] = qlen
        self.ex_start[i] = start
        self.ex_end[i] = end
        self.n_exec = i + 1

    def add_steal(self, time: float, thief: int, victim: int, task: int,
                  dist: int) -> None:
        i = self.n_steal
        if i >= len(self.st_time):
            self._grow(STEAL_COLS)
        self.st_time[i] = time
        self.st_thief[i] = thief
        self.st_victim[i] = victim
        self.st_task[i] = task
        self.st_dist[i] = dist
        self.n_steal = i + 1

    def add_mig(self, time: float, thread: int, frm: int, to: int) -> None:
        i = self.n_mig
        if i >= len(self.mg_time):
            self._grow(MIG_COLS)
        self.mg_time[i] = time
        self.mg_thread[i] = thread
        self.mg_from[i] = frm
        self.mg_to[i] = to
        self.n_mig = i + 1

    # ---- finalization / construction ----

    def finalize(self) -> "TraceBuffer":
        """Trim column arrays to the recorded counts (views, no copy)."""
        if not self._final:
            for name, _ in EXEC_COLS:
                setattr(self, name, getattr(self, name)[:self.n_exec])
            for name, _ in STEAL_COLS:
                setattr(self, name, getattr(self, name)[:self.n_steal])
            for name, _ in MIG_COLS:
                setattr(self, name, getattr(self, name)[:self.n_mig])
            self._final = True
        return self

    @classmethod
    def from_flat(cls, ex_flat, st_flat, mg_flat,
                  meta: "dict | None" = None) -> "TraceBuffer":
        """Build from flat row-major event buffers (the py engine path).

        The engine records an event by ``list.extend``-ing one row
        tuple onto a flat list — the cheapest per-event operation
        available in pure Python — and this constructor columnizes
        each family in two vectorized steps (one bulk float64
        conversion, one strided ``astype`` per column). Integer ids
        round-trip exactly through float64 (they are far below 2**53);
        the py↔C trace-parity tests pin this.
        """
        from array import array

        def cols(flat, spec):
            # array('d', list) converts in C measurably faster than
            # np.asarray on a list of Python scalars
            m = np.frombuffer(array("d", flat) if flat else b"",
                              dtype=np.float64).reshape(-1, len(spec))
            return {name: m[:, i].astype(dt, copy=True)
                    for i, (name, dt) in enumerate(spec)}
        arrays = cols(ex_flat, EXEC_COLS)
        arrays.update(cols(st_flat, STEAL_COLS))
        arrays.update(cols(mg_flat, MIG_COLS))
        return cls.from_arrays(arrays, meta=meta)

    @classmethod
    def from_arrays(cls, arrays: dict, meta: "dict | None" = None,
                    owner=None) -> "TraceBuffer":
        """Wrap pre-built column arrays (zero-copy; C kernel handoff).

        ``owner`` is retained so externally-owned storage (the kernel's
        malloc'd buffers) outlives every numpy view of it.
        """
        tb = cls.__new__(cls)
        for name, dt in ALL_COLS:
            a = np.asarray(arrays[name], dtype=dt)
            setattr(tb, name, a)
        tb.n_exec = int(len(tb.ex_task))
        tb.n_steal = int(len(tb.st_time))
        tb.n_mig = int(len(tb.mg_time))
        tb.meta = dict(meta or {})
        tb._owner = owner
        tb._final = True
        return tb

    # ---- persistence / transport ----

    def __getstate__(self):
        # copy columns so pickles (fork-pool result transport) never
        # reference C-owned storage or oversized capacity arrays.
        self.finalize()
        state = {name: np.ascontiguousarray(getattr(self, name))
                 for name, _ in ALL_COLS}
        state["meta"] = self.meta
        return state

    def __setstate__(self, state):
        meta = state.pop("meta", {})
        tb = TraceBuffer.from_arrays(state, meta=meta)
        self.__dict__.update(tb.__dict__)

    def save_npz(self, path) -> None:
        """Write the trace (columns + meta) to one ``.npz`` file."""
        self.finalize()
        cols = {name: np.ascontiguousarray(getattr(self, name))
                for name, _ in ALL_COLS}
        cols["meta_json"] = np.frombuffer(
            json.dumps(self.meta, sort_keys=True).encode(), dtype=np.uint8)
        np.savez_compressed(path, **cols)

    @classmethod
    def load_npz(cls, path) -> "TraceBuffer":
        with np.load(path) as z:
            meta = {}
            if "meta_json" in z.files:
                meta = json.loads(bytes(z["meta_json"]).decode())
            arrays = {name: z[name] for name, _ in ALL_COLS}
        return cls.from_arrays(arrays, meta=meta)

    # ---- introspection ----

    def __eq__(self, other):
        if not isinstance(other, TraceBuffer):
            return NotImplemented
        self.finalize()
        other.finalize()
        return all(np.array_equal(getattr(self, n), getattr(other, n))
                   for n, _ in ALL_COLS)

    __hash__ = None

    def __repr__(self):
        return (f"TraceBuffer(exec={self.n_exec}, steals={self.n_steal}, "
                f"migrations={self.n_mig})")
