/* Flat-array discrete-event kernel for the NANOS task-runtime simulator.
 *
 * This is a bit-exact transcription of the Python reference engine
 * (repro/core/sim/_engine_py.py), which itself preserves the seed
 * engine's semantics draw-for-draw and float-op-for-float-op:
 *
 *   - event ordering: binary heap keyed (time, seq), seq assigned in
 *     push order exactly as the reference does;
 *   - randomness: MT19937 replicating numpy's legacy RandomState —
 *     shuffle/randint use 32-bit masked rejection (rk_interval),
 *     random_sample uses the two-draw 53-bit recipe (rk_double);
 *   - wake-one parking: a replica of CPython 3.10's set object
 *     (linear probes + perturb, fill*5 >= mask*3 resize, pop finger),
 *     because the seed engine parks threads in a Python set and pops
 *     an arbitrary-but-deterministic element;
 *   - float arithmetic: identical association order, compiled with
 *     -ffp-contract=off so no FMA contraction changes results.
 *
 * All arrays are structure-of-arrays views onto the Python TaskTable;
 * no per-task allocation happens anywhere.
 */

#include <math.h>
#include <stdint.h>
#include <stdlib.h>
#include <string.h>

/* Compiled with -pthread by default; a toolchain without pthread support
 * is retried with -DCSIM_NO_THREADS, which turns the batch worker pool
 * into the plain serial loop (sim_threads_available() reports which). */
#ifndef CSIM_NO_THREADS
#include <pthread.h>
#endif

/* ------------------------------------------------------------------ */
/* MT19937 — numpy legacy RandomState bitstream replica               */
/* ------------------------------------------------------------------ */

#define MT_N 624
#define MT_M 397

typedef struct {
    uint32_t mt[MT_N];
    int mti;
} rk_state;

static void rk_seed(rk_state *st, uint32_t s)
{
    st->mt[0] = s;
    for (int i = 1; i < MT_N; i++)
        st->mt[i] = 1812433253U * (st->mt[i - 1] ^ (st->mt[i - 1] >> 30)) + (uint32_t)i;
    st->mti = MT_N;
}

static uint32_t rk_random(rk_state *st)
{
    uint32_t y;
    if (st->mti >= MT_N) {
        static const uint32_t mag01[2] = {0U, 0x9908b0dfU};
        int kk;
        for (kk = 0; kk < MT_N - MT_M; kk++) {
            y = (st->mt[kk] & 0x80000000U) | (st->mt[kk + 1] & 0x7fffffffU);
            st->mt[kk] = st->mt[kk + MT_M] ^ (y >> 1) ^ mag01[y & 1U];
        }
        for (; kk < MT_N - 1; kk++) {
            y = (st->mt[kk] & 0x80000000U) | (st->mt[kk + 1] & 0x7fffffffU);
            st->mt[kk] = st->mt[kk + (MT_M - MT_N)] ^ (y >> 1) ^ mag01[y & 1U];
        }
        y = (st->mt[MT_N - 1] & 0x80000000U) | (st->mt[0] & 0x7fffffffU);
        st->mt[MT_N - 1] = st->mt[MT_M - 1] ^ (y >> 1) ^ mag01[y & 1U];
        st->mti = 0;
    }
    y = st->mt[st->mti++];
    y ^= y >> 11;
    y ^= (y << 7) & 0x9d2c5680U;
    y ^= (y << 15) & 0xefc60000U;
    y ^= y >> 18;
    return y;
}

/* rk_interval: bounded draw in [0, max] via masked rejection (the draw
 * pattern used by RandomState.shuffle and by scalar randint for ranges
 * that fit in 32 bits). */
static uint32_t rk_interval(rk_state *st, uint32_t max)
{
    uint32_t mask = max, v;
    mask |= mask >> 1; mask |= mask >> 2; mask |= mask >> 4;
    mask |= mask >> 8; mask |= mask >> 16;
    do {
        v = rk_random(st) & mask;
    } while (v > max);
    return v;
}

static double rk_double(rk_state *st)
{
    uint32_t a = rk_random(st) >> 5, b = rk_random(st) >> 6;
    return (a * 67108864.0 + b) / 9007199254740992.0;
}

/* Fisher-Yates matching RandomState.shuffle on a Python list. */
static void rk_shuffle(rk_state *st, int64_t *x, int64_t n)
{
    for (int64_t i = n - 1; i > 0; i--) {
        uint32_t j = rk_interval(st, (uint32_t)i);
        int64_t tmp = x[i]; x[i] = x[j]; x[j] = tmp;
    }
}

/* ------------------------------------------------------------------ */
/* CPython 3.10 set replica (int keys >= 0): add + pop only           */
/* ------------------------------------------------------------------ */

#define SET_MINSIZE 8
#define LINEAR_PROBES 9
#define PERTURB_SHIFT 5

#define SLOT_EMPTY 0
#define SLOT_ACTIVE 1
#define SLOT_DUMMY 2

typedef struct {
    int64_t *key;
    uint8_t *state;
    size_t mask, fill, used, finger;
} pyset_t;

static int pyset_init(pyset_t *s)
{
    s->mask = SET_MINSIZE - 1;
    s->fill = s->used = s->finger = 0;
    s->key = (int64_t *)calloc(SET_MINSIZE, sizeof(int64_t));
    s->state = (uint8_t *)calloc(SET_MINSIZE, 1);
    return (s->key && s->state) ? 0 : -1;
}

static void pyset_free(pyset_t *s)
{
    free(s->key); free(s->state);
}

/* insert into a clean (dummy-free) table; used by resize */
static void pyset_insert_clean(int64_t *keyt, uint8_t *statet, size_t mask,
                               int64_t key)
{
    size_t perturb = (size_t)key;
    size_t i = (size_t)key & mask;
    while (1) {
        size_t j = i;
        size_t probes = (i + LINEAR_PROBES <= mask) ? LINEAR_PROBES : 0;
        do {
            if (statet[j] == SLOT_EMPTY) {
                keyt[j] = key; statet[j] = SLOT_ACTIVE;
                return;
            }
            j++;
        } while (probes--);
        perturb >>= PERTURB_SHIFT;
        i = (i * 5 + 1 + perturb) & mask;
    }
}

static int pyset_resize(pyset_t *s, size_t minused)
{
    size_t newsize = SET_MINSIZE;
    while (newsize <= minused)
        newsize <<= 1;
    int64_t *nk = (int64_t *)calloc(newsize, sizeof(int64_t));
    uint8_t *ns = (uint8_t *)calloc(newsize, 1);
    if (!nk || !ns) { free(nk); free(ns); return -1; }
    for (size_t j = 0; j <= s->mask; j++)
        if (s->state[j] == SLOT_ACTIVE)
            pyset_insert_clean(nk, ns, newsize - 1, s->key[j]);
    free(s->key); free(s->state);
    s->key = nk; s->state = ns;
    s->mask = newsize - 1;
    s->fill = s->used;
    return 0;
}

static int pyset_add(pyset_t *s, int64_t key)
{
    size_t perturb = (size_t)key;
    size_t mask = s->mask;
    size_t i = (size_t)key & mask;
    size_t freeslot = (size_t)-1;
    while (1) {
        size_t j = i;
        size_t probes = (i + LINEAR_PROBES <= mask) ? LINEAR_PROBES : 0;
        do {
            if (s->state[j] == SLOT_EMPTY) {
                if (freeslot != (size_t)-1) {
                    s->used++;
                    s->key[freeslot] = key; s->state[freeslot] = SLOT_ACTIVE;
                    return 0;
                }
                s->fill++; s->used++;
                s->key[j] = key; s->state[j] = SLOT_ACTIVE;
                if (s->fill * 5 < mask * 3)
                    return 0;
                return pyset_resize(s, s->used > 50000 ? s->used * 2
                                                       : s->used * 4);
            }
            if (s->state[j] == SLOT_ACTIVE && s->key[j] == key)
                return 0; /* already present */
            if (s->state[j] == SLOT_DUMMY)
                freeslot = j;
            j++;
        } while (probes--);
        perturb >>= PERTURB_SHIFT;
        i = (i * 5 + 1 + perturb) & mask;
    }
}

static int64_t pyset_pop(pyset_t *s)
{
    size_t i = s->finger & s->mask;
    while (s->state[i] != SLOT_ACTIVE) {
        i++;
        if (i > s->mask)
            i = 0;
    }
    int64_t key = s->key[i];
    s->state[i] = SLOT_DUMMY;
    s->used--;
    s->finger = i + 1;
    return key;
}

/* ------------------------------------------------------------------ */
/* Event heap keyed (time, seq) — indexed, no boxing                  */
/* ------------------------------------------------------------------ */

typedef struct {
    double t;
    uint64_t seq;
    int32_t th;
    int64_t task; /* -1 = acquire-from-pool */
} ev_t;

typedef struct {
    ev_t *e;
    size_t len, cap;
} heap_t;

static int heap_init(heap_t *h, size_t cap)
{
    h->e = (ev_t *)malloc(cap * sizeof(ev_t));
    h->len = 0; h->cap = cap;
    return h->e ? 0 : -1;
}

static inline int ev_lt(const ev_t *a, const ev_t *b)
{
    return a->t < b->t || (a->t == b->t && a->seq < b->seq);
}

static int heap_push(heap_t *h, double t, uint64_t seq, int32_t th, int64_t task)
{
    if (h->len == h->cap) {
        size_t nc = h->cap * 2;
        ev_t *ne = (ev_t *)realloc(h->e, nc * sizeof(ev_t));
        if (!ne) return -1;
        h->e = ne; h->cap = nc;
    }
    size_t i = h->len++;
    ev_t v = {t, seq, th, task};
    while (i > 0) {
        size_t p = (i - 1) >> 1;
        if (!ev_lt(&v, &h->e[p]))
            break;
        h->e[i] = h->e[p];
        i = p;
    }
    h->e[i] = v;
    return 0;
}

static ev_t heap_pop(heap_t *h)
{
    ev_t top = h->e[0];
    ev_t last = h->e[--h->len];
    size_t n = h->len, i = 0;
    while (1) {
        size_t c = 2 * i + 1;
        if (c >= n) break;
        if (c + 1 < n && ev_lt(&h->e[c + 1], &h->e[c]))
            c++;
        if (!ev_lt(&h->e[c], &last))
            break;
        h->e[i] = h->e[c];
        i = c;
    }
    if (n)
        h->e[i] = last;
    return top;
}

/* ------------------------------------------------------------------ */
/* Growable ring deque of task ids                                    */
/* ------------------------------------------------------------------ */

typedef struct {
    int64_t *buf;
    size_t cap, head, len; /* cap is a power of two */
} ring_t;

static int ring_init(ring_t *r, size_t cap)
{
    r->buf = (int64_t *)malloc(cap * sizeof(int64_t));
    r->cap = cap; r->head = 0; r->len = 0;
    return r->buf ? 0 : -1;
}

static int ring_grow(ring_t *r)
{
    size_t nc = r->cap * 2;
    int64_t *nb = (int64_t *)malloc(nc * sizeof(int64_t));
    if (!nb) return -1;
    for (size_t k = 0; k < r->len; k++)
        nb[k] = r->buf[(r->head + k) & (r->cap - 1)];
    free(r->buf);
    r->buf = nb; r->cap = nc; r->head = 0;
    return 0;
}

static inline int ring_push_back(ring_t *r, int64_t v)
{
    if (r->len == r->cap && ring_grow(r))
        return -1;
    r->buf[(r->head + r->len) & (r->cap - 1)] = v;
    r->len++;
    return 0;
}

static inline int64_t ring_pop_back(ring_t *r)
{
    r->len--;
    return r->buf[(r->head + r->len) & (r->cap - 1)];
}

static inline int64_t ring_pop_front(ring_t *r)
{
    int64_t v = r->buf[r->head];
    r->head = (r->head + 1) & (r->cap - 1);
    r->len--;
    return v;
}

/* ------------------------------------------------------------------ */
/* Fault handling (transcribed from _engine_py.go_offline)            */
/* ------------------------------------------------------------------ */

typedef struct {
    heap_t *evq;
    pyset_t *parked;
    ring_t *local;
    ring_t *shared;
    const double *fwend;
    double wake_latency;
    int depth_first;
    uint64_t *seq;
    int64_t *reclaimed;
} fault_env_t;

/* Thread `th` hits offline window `cidx` at `now`, carrying `task` if
 * >= 0. The in-hand task is re-queued (stealable); queued tasks stay
 * in place but one thief is woken per task so they are reclaimed by
 * stealing. A finite window resumes the thread with a fresh acquire at
 * the window end; end == inf is a permanent failure — no resume, and
 * an empty-handed dead thread passes a consumed wake on so live work
 * cannot strand. Returns nonzero on allocation failure. */
static int go_offline(fault_env_t *env, double now, int64_t th,
                      int64_t task, int64_t cidx)
{
    int64_t nq = env->depth_first ? (int64_t)env->local[th].len : 0;
    if (task >= 0) {
        nq++;
        if (env->depth_first) {
            if (ring_push_back(&env->local[th], task)) return -1;
        } else {
            if (ring_push_back(env->shared, task)) return -1;
        }
    }
    *env->reclaimed += nq;
    while (nq > 0 && env->parked->used) {
        ++*env->seq;
        if (heap_push(env->evq, now + env->wake_latency, *env->seq,
                      (int32_t)pyset_pop(env->parked), -1))
            return -1;
        nq--;
    }
    if (env->fwend[cidx] != INFINITY) {
        ++*env->seq;
        if (heap_push(env->evq, env->fwend[cidx], *env->seq,
                      (int32_t)th, -1))
            return -1;
    } else if (task < 0 && env->parked->used) {
        ++*env->seq;
        if (heap_push(env->evq, now, *env->seq,
                      (int32_t)pyset_pop(env->parked), -1))
            return -1;
    }
    return 0;
}

/* ------------------------------------------------------------------ */
/* Event-trace capture (see sim/trace.py for the record semantics)    */
/* ------------------------------------------------------------------ */

/* Structure-of-arrays trace buffer. Capacity-planned from the task
 * count (trace.plan_capacity mirrors this) and grown geometrically, so
 * paper-scale traces amortize to O(1) allocations per event family.
 * The arrays are malloc'd here and handed back to Python zero-copy
 * (numpy views over the raw pointers; sim_trace_free releases them
 * when the last view dies). */
typedef struct {
    int64_t *ex_task, *ex_thread, *ex_core, *ex_node, *ex_qlen;
    double *ex_start, *ex_end;
    double *st_time;
    int64_t *st_thief, *st_victim, *st_task, *st_dist;
    double *mg_time;
    int64_t *mg_thread, *mg_from, *mg_to;
    int64_t n_exec, n_steal, n_mig;
    int64_t ex_cap, st_cap, mg_cap;
} trace_t;

static void trace_free_arrays(trace_t *tp)
{
    free(tp->ex_task); free(tp->ex_thread); free(tp->ex_core);
    free(tp->ex_node); free(tp->ex_qlen);
    free(tp->ex_start); free(tp->ex_end);
    free(tp->st_time); free(tp->st_thief); free(tp->st_victim);
    free(tp->st_task); free(tp->st_dist);
    free(tp->mg_time); free(tp->mg_thread); free(tp->mg_from);
    free(tp->mg_to);
}

/* Allocate a trace for an n_tasks run: every task commits exactly one
 * exec event fault-free, so the exec family is exact up front; steal /
 * migration counts are workload-dependent and start small. Returns
 * NULL on allocation failure. */
void *sim_trace_new(int64_t n_tasks)
{
    trace_t *tp = (trace_t *)calloc(1, sizeof(trace_t));
    if (!tp)
        return NULL;
    int64_t n = n_tasks > 1 ? n_tasks : 1;
    tp->ex_cap = n;
    tp->st_cap = n / 8 > 64 ? n / 8 : 64;
    tp->mg_cap = 64;
    tp->ex_task = (int64_t *)malloc((size_t)tp->ex_cap * sizeof(int64_t));
    tp->ex_thread = (int64_t *)malloc((size_t)tp->ex_cap * sizeof(int64_t));
    tp->ex_core = (int64_t *)malloc((size_t)tp->ex_cap * sizeof(int64_t));
    tp->ex_node = (int64_t *)malloc((size_t)tp->ex_cap * sizeof(int64_t));
    tp->ex_qlen = (int64_t *)malloc((size_t)tp->ex_cap * sizeof(int64_t));
    tp->ex_start = (double *)malloc((size_t)tp->ex_cap * sizeof(double));
    tp->ex_end = (double *)malloc((size_t)tp->ex_cap * sizeof(double));
    tp->st_time = (double *)malloc((size_t)tp->st_cap * sizeof(double));
    tp->st_thief = (int64_t *)malloc((size_t)tp->st_cap * sizeof(int64_t));
    tp->st_victim = (int64_t *)malloc((size_t)tp->st_cap * sizeof(int64_t));
    tp->st_task = (int64_t *)malloc((size_t)tp->st_cap * sizeof(int64_t));
    tp->st_dist = (int64_t *)malloc((size_t)tp->st_cap * sizeof(int64_t));
    tp->mg_time = (double *)malloc((size_t)tp->mg_cap * sizeof(double));
    tp->mg_thread = (int64_t *)malloc((size_t)tp->mg_cap * sizeof(int64_t));
    tp->mg_from = (int64_t *)malloc((size_t)tp->mg_cap * sizeof(int64_t));
    tp->mg_to = (int64_t *)malloc((size_t)tp->mg_cap * sizeof(int64_t));
    if (!tp->ex_task || !tp->ex_thread || !tp->ex_core || !tp->ex_node ||
        !tp->ex_qlen || !tp->ex_start || !tp->ex_end ||
        !tp->st_time || !tp->st_thief || !tp->st_victim || !tp->st_task ||
        !tp->st_dist ||
        !tp->mg_time || !tp->mg_thread || !tp->mg_from || !tp->mg_to) {
        trace_free_arrays(tp);
        free(tp);
        return NULL;
    }
    return tp;
}

void sim_trace_free(void *p)
{
    trace_t *tp = (trace_t *)p;
    if (!tp)
        return;
    trace_free_arrays(tp);
    free(tp);
}

/* Event counts: out3 = [n_exec, n_steal, n_mig]. */
void sim_trace_counts(void *p, int64_t *out3)
{
    trace_t *tp = (trace_t *)p;
    out3[0] = tp->n_exec;
    out3[1] = tp->n_steal;
    out3[2] = tp->n_mig;
}

/* Column pointers, in the trace.py ALL_COLS order:
 * [ex_task, ex_thread, ex_core, ex_node, ex_qlen, ex_start, ex_end,
 *  st_time, st_thief, st_victim, st_task, st_dist,
 *  mg_time, mg_thread, mg_from, mg_to]. */
void sim_trace_ptrs(void *p, void **out16)
{
    trace_t *tp = (trace_t *)p;
    out16[0] = tp->ex_task;  out16[1] = tp->ex_thread;
    out16[2] = tp->ex_core;  out16[3] = tp->ex_node;
    out16[4] = tp->ex_qlen;  out16[5] = tp->ex_start;
    out16[6] = tp->ex_end;
    out16[7] = tp->st_time;  out16[8] = tp->st_thief;
    out16[9] = tp->st_victim; out16[10] = tp->st_task;
    out16[11] = tp->st_dist;
    out16[12] = tp->mg_time; out16[13] = tp->mg_thread;
    out16[14] = tp->mg_from; out16[15] = tp->mg_to;
}

#define TRACE_GROW(arr, ty, cap)                                        \
    do {                                                                \
        ty *nb_ = (ty *)realloc(tp->arr, (size_t)(cap) * sizeof(ty));   \
        if (!nb_) return -1;                                            \
        tp->arr = nb_;                                                  \
    } while (0)

static int trace_exec(trace_t *tp, int64_t task, int64_t th, int64_t core,
                      int64_t node, int64_t qlen, double start, double end)
{
    int64_t i = tp->n_exec;
    if (i >= tp->ex_cap) {
        int64_t nc2 = tp->ex_cap * 2;
        TRACE_GROW(ex_task, int64_t, nc2);
        TRACE_GROW(ex_thread, int64_t, nc2);
        TRACE_GROW(ex_core, int64_t, nc2);
        TRACE_GROW(ex_node, int64_t, nc2);
        TRACE_GROW(ex_qlen, int64_t, nc2);
        TRACE_GROW(ex_start, double, nc2);
        TRACE_GROW(ex_end, double, nc2);
        tp->ex_cap = nc2;
    }
    tp->ex_task[i] = task;
    tp->ex_thread[i] = th;
    tp->ex_core[i] = core;
    tp->ex_node[i] = node;
    tp->ex_qlen[i] = qlen;
    tp->ex_start[i] = start;
    tp->ex_end[i] = end;
    tp->n_exec = i + 1;
    return 0;
}

static int trace_steal(trace_t *tp, double t, int64_t thief, int64_t victim,
                       int64_t task, int64_t dist)
{
    int64_t i = tp->n_steal;
    if (i >= tp->st_cap) {
        int64_t nc2 = tp->st_cap * 2;
        TRACE_GROW(st_time, double, nc2);
        TRACE_GROW(st_thief, int64_t, nc2);
        TRACE_GROW(st_victim, int64_t, nc2);
        TRACE_GROW(st_task, int64_t, nc2);
        TRACE_GROW(st_dist, int64_t, nc2);
        tp->st_cap = nc2;
    }
    tp->st_time[i] = t;
    tp->st_thief[i] = thief;
    tp->st_victim[i] = victim;
    tp->st_task[i] = task;
    tp->st_dist[i] = dist;
    tp->n_steal = i + 1;
    return 0;
}

static int trace_mig(trace_t *tp, double t, int64_t th, int64_t from,
                     int64_t to)
{
    int64_t i = tp->n_mig;
    if (i >= tp->mg_cap) {
        int64_t nc2 = tp->mg_cap * 2;
        TRACE_GROW(mg_time, double, nc2);
        TRACE_GROW(mg_thread, int64_t, nc2);
        TRACE_GROW(mg_from, int64_t, nc2);
        TRACE_GROW(mg_to, int64_t, nc2);
        tp->mg_cap = nc2;
    }
    tp->mg_time[i] = t;
    tp->mg_thread[i] = th;
    tp->mg_from[i] = from;
    tp->mg_to[i] = to;
    tp->n_mig = i + 1;
    return 0;
}

/* ------------------------------------------------------------------ */
/* Simulator                                                          */
/* ------------------------------------------------------------------ */

/* Victim plan (compiled by policy.py): per thread a run of *groups*
 * (group_off), each group a run of *units* (unit_off), each unit a
 * contiguous run of victim ids (victim_off into victims). A sweep
 * emits groups in order; a group with >1 unit first shuffles the unit
 * order (one Fisher-Yates of unit-count elements — the only rng the
 * sweep consumes, matching the seed engine's per-group shuffles).
 *
 * dpar: [hop_lambda, hop_lambda_steal, lock_time, deque_lock_time,
 *        steal_time, spawn_time, wake_latency, qop_time, cache_refill,
 *        mem_intensity, migration_rate]
 * ipar: [T, num_cores, num_nodes, n_tasks, queue_shared, child_first,
 *        seed, runtime_data_node(-1=none), root_node0, has_faults,
 *        max_steps(<=0 = unlimited)]
 * fault plan (consulted only when has_faults): fspeed (num_cores
 * per-core cost multipliers), fwoff (T+1 CSR offsets), fwstart/fwend
 * (merged offline windows per thread; end == inf = permanent failure)
 * dout: [makespan, remote, total_exec, queue_wait, fault_lost, last_t]
 * iout: [steals, failed_probes, reclaimed, reexec, executed, steps,
 *        status(0 ok, 1 watchdog, 2 stranded work)]
 * agg_steal_hops / agg_node_tasks / agg_node_remote: caller-allocated,
 * zeroed aggregate counters (successful steals per hop distance, tasks
 * executed per node, NUMA penalty time per node) — always recorded.
 * trace: a sim_trace_new() handle for full event capture, or NULL; the
 * untraced code path is a separate compilation of the loop with every
 * recording site preprocessed out (see _csim_core.h).
 * returns 0 on success, negative on allocation failure.
 */

#define CSIM_TRACED 0
#define CSIM_NAME sim_run_notrace
#include "_csim_core.h"
#undef CSIM_TRACED
#undef CSIM_NAME

#define CSIM_TRACED 1
#define CSIM_NAME sim_run_traced
#include "_csim_core.h"
#undef CSIM_TRACED
#undef CSIM_NAME

int sim_run(const double *dpar, const int64_t *ipar,
            const double *wp, const double *wpo,
            const double *fr, const double *fp,
            const int64_t *fc, const int64_t *nc,
            const int64_t *fpw, const int64_t *npw,
            const int64_t *par,
            const int64_t *core_node, const int64_t *node_dist,
            const double *root_dist,
            int64_t *cores,
            const int64_t *vp_group_off,   /* T+1 */
            const int64_t *vp_unit_off,    /* n_groups+1 */
            const int64_t *vp_victim_off,  /* n_units+1 */
            const int64_t *vp_victims,     /* total victim slots */
            const double *fspeed,          /* num_cores (faults) */
            const int64_t *fwoff,          /* T+1 (faults) */
            const double *fwstart,         /* n_windows (faults) */
            const double *fwend,           /* n_windows (faults) */
            double *dout, int64_t *iout,
            int64_t *agg_steal_hops, int64_t *agg_node_tasks,
            double *agg_node_remote, void *trace)
{
    if (trace)
        return sim_run_traced(dpar, ipar, wp, wpo, fr, fp, fc, nc, fpw,
                              npw, par, core_node, node_dist, root_dist,
                              cores, vp_group_off, vp_unit_off,
                              vp_victim_off, vp_victims, fspeed, fwoff,
                              fwstart, fwend, dout, iout, agg_steal_hops,
                              agg_node_tasks, agg_node_remote,
                              (trace_t *)trace);
    return sim_run_notrace(dpar, ipar, wp, wpo, fr, fp, fc, nc, fpw,
                           npw, par, core_node, node_dist, root_dist,
                           cores, vp_group_off, vp_unit_off,
                           vp_victim_off, vp_victims, fspeed, fwoff,
                           fwstart, fwend, dout, iout, agg_steal_hops,
                           agg_node_tasks, agg_node_remote, NULL);
}
/* ------------------------------------------------------------------ */
/* Batched sweep entry — multi-threaded cell dispatch                 */
/* ------------------------------------------------------------------ */

/* Every per-config argument arrives as an array of pointers (one per
 * config, same order as the sim_run parameters). Workers pull cell
 * indices from an atomic counter; each sim_run call is self-contained
 * (private heap/queues/rng on the worker's stack+heap, no globals) and
 * writes to its own dout/iout/rc slot, so results are ordered and
 * bit-identical to the serial loop regardless of worker count. */

typedef struct {
    int64_t n_cfg;
    void **a[27];        /* the 27 per-config pointer tables, in order */
    double *dout;        /* 6 slots per config */
    int64_t *iout;       /* 7 slots per config */
    int64_t *rc;         /* per-config sim_run return code */
    volatile int64_t next;
} batch_t;

static void batch_run_one(batch_t *b, int64_t i)
{
    void **const *a = b->a;
    b->rc[i] = (int64_t)sim_run(
        (const double *)a[0][i], (const int64_t *)a[1][i],
        (const double *)a[2][i], (const double *)a[3][i],
        (const double *)a[4][i], (const double *)a[5][i],
        (const int64_t *)a[6][i], (const int64_t *)a[7][i],
        (const int64_t *)a[8][i], (const int64_t *)a[9][i],
        (const int64_t *)a[10][i],
        (const int64_t *)a[11][i], (const int64_t *)a[12][i],
        (const double *)a[13][i],
        (int64_t *)a[14][i],
        (const int64_t *)a[15][i], (const int64_t *)a[16][i],
        (const int64_t *)a[17][i], (const int64_t *)a[18][i],
        (const double *)a[19][i], (const int64_t *)a[20][i],
        (const double *)a[21][i], (const double *)a[22][i],
        b->dout + 6 * i, b->iout + 7 * i,
        (int64_t *)a[23][i], (int64_t *)a[24][i],
        (double *)a[25][i], a[26][i]);
}

#ifndef CSIM_NO_THREADS
static void *batch_worker(void *arg)
{
    batch_t *b = (batch_t *)arg;
    for (;;) {
        int64_t i = __sync_fetch_and_add(&b->next, 1);
        if (i >= b->n_cfg)
            break;
        batch_run_one(b, i);
    }
    return NULL;
}
#endif

/* 1 when the library was built with the pthread worker pool. */
int sim_threads_available(void)
{
#ifdef CSIM_NO_THREADS
    return 0;
#else
    return 1;
#endif
}

/* Run n_cfg prepared configs on n_workers threads (n_workers <= 1, a
 * single config, or a -DCSIM_NO_THREADS build: the serial loop, exactly
 * the pre-pool code path). rc_out[i] receives each config's sim_run
 * return code (0 ok, negative = allocation failure); failing configs do
 * not stop the rest of the batch. Returns the number of failed configs.
 */
int64_t sim_run_batch(int64_t n_cfg, int64_t n_workers,
                      void **dpar, void **ipar,
                      void **wp, void **wpo, void **fr, void **fp,
                      void **fc, void **nc, void **fpw, void **npw,
                      void **par,
                      void **core_node, void **node_dist, void **root_dist,
                      void **cores,
                      void **vp_group_off, void **vp_unit_off,
                      void **vp_victim_off, void **vp_victims,
                      void **fspeed, void **fwoff,
                      void **fwstart, void **fwend,
                      void **agg_steal_hops, void **agg_node_tasks,
                      void **agg_node_remote, void **trace,
                      double *dout, int64_t *iout, int64_t *rc_out)
{
    batch_t b;
    b.n_cfg = n_cfg;
    b.a[0] = dpar; b.a[1] = ipar; b.a[2] = wp; b.a[3] = wpo;
    b.a[4] = fr; b.a[5] = fp; b.a[6] = fc; b.a[7] = nc;
    b.a[8] = fpw; b.a[9] = npw; b.a[10] = par;
    b.a[11] = core_node; b.a[12] = node_dist; b.a[13] = root_dist;
    b.a[14] = cores;
    b.a[15] = vp_group_off; b.a[16] = vp_unit_off;
    b.a[17] = vp_victim_off; b.a[18] = vp_victims;
    b.a[19] = fspeed; b.a[20] = fwoff;
    b.a[21] = fwstart; b.a[22] = fwend;
    b.a[23] = agg_steal_hops; b.a[24] = agg_node_tasks;
    b.a[25] = agg_node_remote; b.a[26] = trace;
    b.dout = dout;
    b.iout = iout;
    b.rc = rc_out;
    b.next = 0;

    if (n_workers > n_cfg)
        n_workers = n_cfg;
#ifndef CSIM_NO_THREADS
    if (n_workers > 1) {
        if (n_workers > 1024)
            n_workers = 1024;
        pthread_t *tids = (pthread_t *)malloc((size_t)(n_workers - 1)
                                              * sizeof(pthread_t));
        int64_t spawned = 0;
        if (tids) {
            for (int64_t k = 0; k < n_workers - 1; k++)
                if (pthread_create(&tids[spawned], NULL,
                                   batch_worker, &b) == 0)
                    spawned++;
        }
        /* the calling thread is worker 0; a partially (or fully)
         * failed spawn just means fewer helpers — the atomic counter
         * still drains every cell */
        batch_worker(&b);
        for (int64_t k = 0; k < spawned; k++)
            pthread_join(tids[k], NULL);
        free(tids);
    } else
#endif
    {
        for (int64_t i = 0; i < n_cfg; i++)
            batch_run_one(&b, i);
    }

    int64_t nfail = 0;
    for (int64_t i = 0; i < n_cfg; i++)
        if (rc_out[i] != 0)
            nfail++;
    return nfail;
}

/* ------------------------------------------------------------------ */
/* Self-test hooks (used by the test suite to fuzz the replicas)      */
/* ------------------------------------------------------------------ */

/* Raw MT draws, to compare against numpy's randint(0, 2**32, uint32). */
void mt_selftest(uint32_t seed, int64_t n, uint32_t *out)
{
    rk_state st;
    rk_seed(&st, seed);
    for (int64_t i = 0; i < n; i++)
        out[i] = rk_random(&st);
}

/* Shuffle replica: shuffles arange(n) repeatedly, writing each result. */
void shuffle_selftest(uint32_t seed, int64_t n, int64_t reps, int64_t *out)
{
    rk_state st;
    rk_seed(&st, seed);
    for (int64_t r = 0; r < reps; r++) {
        int64_t *row = out + r * n;
        for (int64_t i = 0; i < n; i++)
            row[i] = i;
        rk_shuffle(&st, row, n);
    }
}

/* Set replica: ops[i] >= 0 -> add(ops[i]); ops[i] == -1 -> pop.
 * Popped values are appended to out; returns number of pops. */
int64_t set_selftest(int64_t nops, const int64_t *ops, int64_t *out)
{
    pyset_t s;
    if (pyset_init(&s))
        return -1;
    int64_t npop = 0;
    for (int64_t i = 0; i < nops; i++) {
        if (ops[i] >= 0) {
            if (pyset_add(&s, ops[i])) { pyset_free(&s); return -1; }
        } else if (s.used) {
            out[npop++] = pyset_pop(&s);
        }
    }
    pyset_free(&s);
    return npop;
}
