/* Flat-array discrete-event kernel for the NANOS task-runtime simulator.
 *
 * This is a bit-exact transcription of the Python reference engine
 * (repro/core/sim/_engine_py.py), which itself preserves the seed
 * engine's semantics draw-for-draw and float-op-for-float-op:
 *
 *   - event ordering: binary heap keyed (time, seq), seq assigned in
 *     push order exactly as the reference does;
 *   - randomness: MT19937 replicating numpy's legacy RandomState —
 *     shuffle/randint use 32-bit masked rejection (rk_interval),
 *     random_sample uses the two-draw 53-bit recipe (rk_double);
 *   - wake-one parking: a replica of CPython 3.10's set object
 *     (linear probes + perturb, fill*5 >= mask*3 resize, pop finger),
 *     because the seed engine parks threads in a Python set and pops
 *     an arbitrary-but-deterministic element;
 *   - float arithmetic: identical association order, compiled with
 *     -ffp-contract=off so no FMA contraction changes results.
 *
 * All arrays are structure-of-arrays views onto the Python TaskTable;
 * no per-task allocation happens anywhere.
 */

#include <math.h>
#include <stdint.h>
#include <stdlib.h>
#include <string.h>

/* Compiled with -pthread by default; a toolchain without pthread support
 * is retried with -DCSIM_NO_THREADS, which turns the batch worker pool
 * into the plain serial loop (sim_threads_available() reports which). */
#ifndef CSIM_NO_THREADS
#include <pthread.h>
#endif

/* ------------------------------------------------------------------ */
/* MT19937 — numpy legacy RandomState bitstream replica               */
/* ------------------------------------------------------------------ */

#define MT_N 624
#define MT_M 397

typedef struct {
    uint32_t mt[MT_N];
    int mti;
} rk_state;

static void rk_seed(rk_state *st, uint32_t s)
{
    st->mt[0] = s;
    for (int i = 1; i < MT_N; i++)
        st->mt[i] = 1812433253U * (st->mt[i - 1] ^ (st->mt[i - 1] >> 30)) + (uint32_t)i;
    st->mti = MT_N;
}

static uint32_t rk_random(rk_state *st)
{
    uint32_t y;
    if (st->mti >= MT_N) {
        static const uint32_t mag01[2] = {0U, 0x9908b0dfU};
        int kk;
        for (kk = 0; kk < MT_N - MT_M; kk++) {
            y = (st->mt[kk] & 0x80000000U) | (st->mt[kk + 1] & 0x7fffffffU);
            st->mt[kk] = st->mt[kk + MT_M] ^ (y >> 1) ^ mag01[y & 1U];
        }
        for (; kk < MT_N - 1; kk++) {
            y = (st->mt[kk] & 0x80000000U) | (st->mt[kk + 1] & 0x7fffffffU);
            st->mt[kk] = st->mt[kk + (MT_M - MT_N)] ^ (y >> 1) ^ mag01[y & 1U];
        }
        y = (st->mt[MT_N - 1] & 0x80000000U) | (st->mt[0] & 0x7fffffffU);
        st->mt[MT_N - 1] = st->mt[MT_M - 1] ^ (y >> 1) ^ mag01[y & 1U];
        st->mti = 0;
    }
    y = st->mt[st->mti++];
    y ^= y >> 11;
    y ^= (y << 7) & 0x9d2c5680U;
    y ^= (y << 15) & 0xefc60000U;
    y ^= y >> 18;
    return y;
}

/* rk_interval: bounded draw in [0, max] via masked rejection (the draw
 * pattern used by RandomState.shuffle and by scalar randint for ranges
 * that fit in 32 bits). */
static uint32_t rk_interval(rk_state *st, uint32_t max)
{
    uint32_t mask = max, v;
    mask |= mask >> 1; mask |= mask >> 2; mask |= mask >> 4;
    mask |= mask >> 8; mask |= mask >> 16;
    do {
        v = rk_random(st) & mask;
    } while (v > max);
    return v;
}

static double rk_double(rk_state *st)
{
    uint32_t a = rk_random(st) >> 5, b = rk_random(st) >> 6;
    return (a * 67108864.0 + b) / 9007199254740992.0;
}

/* Fisher-Yates matching RandomState.shuffle on a Python list. */
static void rk_shuffle(rk_state *st, int64_t *x, int64_t n)
{
    for (int64_t i = n - 1; i > 0; i--) {
        uint32_t j = rk_interval(st, (uint32_t)i);
        int64_t tmp = x[i]; x[i] = x[j]; x[j] = tmp;
    }
}

/* ------------------------------------------------------------------ */
/* CPython 3.10 set replica (int keys >= 0): add + pop only           */
/* ------------------------------------------------------------------ */

#define SET_MINSIZE 8
#define LINEAR_PROBES 9
#define PERTURB_SHIFT 5

#define SLOT_EMPTY 0
#define SLOT_ACTIVE 1
#define SLOT_DUMMY 2

typedef struct {
    int64_t *key;
    uint8_t *state;
    size_t mask, fill, used, finger;
} pyset_t;

static int pyset_init(pyset_t *s)
{
    s->mask = SET_MINSIZE - 1;
    s->fill = s->used = s->finger = 0;
    s->key = (int64_t *)calloc(SET_MINSIZE, sizeof(int64_t));
    s->state = (uint8_t *)calloc(SET_MINSIZE, 1);
    return (s->key && s->state) ? 0 : -1;
}

static void pyset_free(pyset_t *s)
{
    free(s->key); free(s->state);
}

/* insert into a clean (dummy-free) table; used by resize */
static void pyset_insert_clean(int64_t *keyt, uint8_t *statet, size_t mask,
                               int64_t key)
{
    size_t perturb = (size_t)key;
    size_t i = (size_t)key & mask;
    while (1) {
        size_t j = i;
        size_t probes = (i + LINEAR_PROBES <= mask) ? LINEAR_PROBES : 0;
        do {
            if (statet[j] == SLOT_EMPTY) {
                keyt[j] = key; statet[j] = SLOT_ACTIVE;
                return;
            }
            j++;
        } while (probes--);
        perturb >>= PERTURB_SHIFT;
        i = (i * 5 + 1 + perturb) & mask;
    }
}

static int pyset_resize(pyset_t *s, size_t minused)
{
    size_t newsize = SET_MINSIZE;
    while (newsize <= minused)
        newsize <<= 1;
    int64_t *nk = (int64_t *)calloc(newsize, sizeof(int64_t));
    uint8_t *ns = (uint8_t *)calloc(newsize, 1);
    if (!nk || !ns) { free(nk); free(ns); return -1; }
    for (size_t j = 0; j <= s->mask; j++)
        if (s->state[j] == SLOT_ACTIVE)
            pyset_insert_clean(nk, ns, newsize - 1, s->key[j]);
    free(s->key); free(s->state);
    s->key = nk; s->state = ns;
    s->mask = newsize - 1;
    s->fill = s->used;
    return 0;
}

static int pyset_add(pyset_t *s, int64_t key)
{
    size_t perturb = (size_t)key;
    size_t mask = s->mask;
    size_t i = (size_t)key & mask;
    size_t freeslot = (size_t)-1;
    while (1) {
        size_t j = i;
        size_t probes = (i + LINEAR_PROBES <= mask) ? LINEAR_PROBES : 0;
        do {
            if (s->state[j] == SLOT_EMPTY) {
                if (freeslot != (size_t)-1) {
                    s->used++;
                    s->key[freeslot] = key; s->state[freeslot] = SLOT_ACTIVE;
                    return 0;
                }
                s->fill++; s->used++;
                s->key[j] = key; s->state[j] = SLOT_ACTIVE;
                if (s->fill * 5 < mask * 3)
                    return 0;
                return pyset_resize(s, s->used > 50000 ? s->used * 2
                                                       : s->used * 4);
            }
            if (s->state[j] == SLOT_ACTIVE && s->key[j] == key)
                return 0; /* already present */
            if (s->state[j] == SLOT_DUMMY)
                freeslot = j;
            j++;
        } while (probes--);
        perturb >>= PERTURB_SHIFT;
        i = (i * 5 + 1 + perturb) & mask;
    }
}

static int64_t pyset_pop(pyset_t *s)
{
    size_t i = s->finger & s->mask;
    while (s->state[i] != SLOT_ACTIVE) {
        i++;
        if (i > s->mask)
            i = 0;
    }
    int64_t key = s->key[i];
    s->state[i] = SLOT_DUMMY;
    s->used--;
    s->finger = i + 1;
    return key;
}

/* ------------------------------------------------------------------ */
/* Event heap keyed (time, seq) — indexed, no boxing                  */
/* ------------------------------------------------------------------ */

typedef struct {
    double t;
    uint64_t seq;
    int32_t th;
    int64_t task; /* -1 = acquire-from-pool */
} ev_t;

typedef struct {
    ev_t *e;
    size_t len, cap;
} heap_t;

static int heap_init(heap_t *h, size_t cap)
{
    h->e = (ev_t *)malloc(cap * sizeof(ev_t));
    h->len = 0; h->cap = cap;
    return h->e ? 0 : -1;
}

static inline int ev_lt(const ev_t *a, const ev_t *b)
{
    return a->t < b->t || (a->t == b->t && a->seq < b->seq);
}

static int heap_push(heap_t *h, double t, uint64_t seq, int32_t th, int64_t task)
{
    if (h->len == h->cap) {
        size_t nc = h->cap * 2;
        ev_t *ne = (ev_t *)realloc(h->e, nc * sizeof(ev_t));
        if (!ne) return -1;
        h->e = ne; h->cap = nc;
    }
    size_t i = h->len++;
    ev_t v = {t, seq, th, task};
    while (i > 0) {
        size_t p = (i - 1) >> 1;
        if (!ev_lt(&v, &h->e[p]))
            break;
        h->e[i] = h->e[p];
        i = p;
    }
    h->e[i] = v;
    return 0;
}

static ev_t heap_pop(heap_t *h)
{
    ev_t top = h->e[0];
    ev_t last = h->e[--h->len];
    size_t n = h->len, i = 0;
    while (1) {
        size_t c = 2 * i + 1;
        if (c >= n) break;
        if (c + 1 < n && ev_lt(&h->e[c + 1], &h->e[c]))
            c++;
        if (!ev_lt(&h->e[c], &last))
            break;
        h->e[i] = h->e[c];
        i = c;
    }
    if (n)
        h->e[i] = last;
    return top;
}

/* ------------------------------------------------------------------ */
/* Growable ring deque of task ids                                    */
/* ------------------------------------------------------------------ */

typedef struct {
    int64_t *buf;
    size_t cap, head, len; /* cap is a power of two */
} ring_t;

static int ring_init(ring_t *r, size_t cap)
{
    r->buf = (int64_t *)malloc(cap * sizeof(int64_t));
    r->cap = cap; r->head = 0; r->len = 0;
    return r->buf ? 0 : -1;
}

static int ring_grow(ring_t *r)
{
    size_t nc = r->cap * 2;
    int64_t *nb = (int64_t *)malloc(nc * sizeof(int64_t));
    if (!nb) return -1;
    for (size_t k = 0; k < r->len; k++)
        nb[k] = r->buf[(r->head + k) & (r->cap - 1)];
    free(r->buf);
    r->buf = nb; r->cap = nc; r->head = 0;
    return 0;
}

static inline int ring_push_back(ring_t *r, int64_t v)
{
    if (r->len == r->cap && ring_grow(r))
        return -1;
    r->buf[(r->head + r->len) & (r->cap - 1)] = v;
    r->len++;
    return 0;
}

static inline int64_t ring_pop_back(ring_t *r)
{
    r->len--;
    return r->buf[(r->head + r->len) & (r->cap - 1)];
}

static inline int64_t ring_pop_front(ring_t *r)
{
    int64_t v = r->buf[r->head];
    r->head = (r->head + 1) & (r->cap - 1);
    r->len--;
    return v;
}

/* ------------------------------------------------------------------ */
/* Fault handling (transcribed from _engine_py.go_offline)            */
/* ------------------------------------------------------------------ */

typedef struct {
    heap_t *evq;
    pyset_t *parked;
    ring_t *local;
    ring_t *shared;
    const double *fwend;
    double wake_latency;
    int depth_first;
    uint64_t *seq;
    int64_t *reclaimed;
} fault_env_t;

/* Thread `th` hits offline window `cidx` at `now`, carrying `task` if
 * >= 0. The in-hand task is re-queued (stealable); queued tasks stay
 * in place but one thief is woken per task so they are reclaimed by
 * stealing. A finite window resumes the thread with a fresh acquire at
 * the window end; end == inf is a permanent failure — no resume, and
 * an empty-handed dead thread passes a consumed wake on so live work
 * cannot strand. Returns nonzero on allocation failure. */
static int go_offline(fault_env_t *env, double now, int64_t th,
                      int64_t task, int64_t cidx)
{
    int64_t nq = env->depth_first ? (int64_t)env->local[th].len : 0;
    if (task >= 0) {
        nq++;
        if (env->depth_first) {
            if (ring_push_back(&env->local[th], task)) return -1;
        } else {
            if (ring_push_back(env->shared, task)) return -1;
        }
    }
    *env->reclaimed += nq;
    while (nq > 0 && env->parked->used) {
        ++*env->seq;
        if (heap_push(env->evq, now + env->wake_latency, *env->seq,
                      (int32_t)pyset_pop(env->parked), -1))
            return -1;
        nq--;
    }
    if (env->fwend[cidx] != INFINITY) {
        ++*env->seq;
        if (heap_push(env->evq, env->fwend[cidx], *env->seq,
                      (int32_t)th, -1))
            return -1;
    } else if (task < 0 && env->parked->used) {
        ++*env->seq;
        if (heap_push(env->evq, now, *env->seq,
                      (int32_t)pyset_pop(env->parked), -1))
            return -1;
    }
    return 0;
}

/* ------------------------------------------------------------------ */
/* Simulator                                                          */
/* ------------------------------------------------------------------ */

/* Victim plan (compiled by policy.py): per thread a run of *groups*
 * (group_off), each group a run of *units* (unit_off), each unit a
 * contiguous run of victim ids (victim_off into victims). A sweep
 * emits groups in order; a group with >1 unit first shuffles the unit
 * order (one Fisher-Yates of unit-count elements — the only rng the
 * sweep consumes, matching the seed engine's per-group shuffles).
 *
 * dpar: [hop_lambda, hop_lambda_steal, lock_time, deque_lock_time,
 *        steal_time, spawn_time, wake_latency, qop_time, cache_refill,
 *        mem_intensity, migration_rate]
 * ipar: [T, num_cores, num_nodes, n_tasks, queue_shared, child_first,
 *        seed, runtime_data_node(-1=none), root_node0, has_faults,
 *        max_steps(<=0 = unlimited)]
 * fault plan (consulted only when has_faults): fspeed (num_cores
 * per-core cost multipliers), fwoff (T+1 CSR offsets), fwstart/fwend
 * (merged offline windows per thread; end == inf = permanent failure)
 * dout: [makespan, remote, total_exec, queue_wait, fault_lost, last_t]
 * iout: [steals, failed_probes, reclaimed, reexec, executed, steps,
 *        status(0 ok, 1 watchdog, 2 stranded work)]
 * returns 0 on success, negative on allocation failure.
 */
int sim_run(const double *dpar, const int64_t *ipar,
            const double *wp, const double *wpo,
            const double *fr, const double *fp,
            const int64_t *fc, const int64_t *nc,
            const int64_t *fpw, const int64_t *npw,
            const int64_t *par,
            const int64_t *core_node, const int64_t *node_dist,
            const double *root_dist,
            int64_t *cores,
            const int64_t *vp_group_off,   /* T+1 */
            const int64_t *vp_unit_off,    /* n_groups+1 */
            const int64_t *vp_victim_off,  /* n_units+1 */
            const int64_t *vp_victims,     /* total victim slots */
            const double *fspeed,          /* num_cores (faults) */
            const int64_t *fwoff,          /* T+1 (faults) */
            const double *fwstart,         /* n_windows (faults) */
            const double *fwend,           /* n_windows (faults) */
            double *dout, int64_t *iout)
{
    const double hop_lambda = dpar[0], hop_lambda_steal = dpar[1];
    const double lock_time = dpar[2], deque_lock_time = dpar[3];
    const double steal_time = dpar[4], spawn_time = dpar[5];
    const double wake_latency = dpar[6], qop_time = dpar[7];
    const double cache_refill = dpar[8], mem_intensity = dpar[9];
    const double migration_rate = dpar[10];
    const int64_t T = ipar[0], num_cores = ipar[1], NN = ipar[2];
    const int64_t n_tasks = ipar[3];
    const int depth_first = !ipar[4];
    const int wf_like = (int)ipar[5];
    const uint32_t seed = (uint32_t)ipar[6];
    const int64_t rdn = ipar[7];
    const int64_t rnode0 = ipar[8];
    const int has_faults = (int)ipar[9];
    int64_t max_steps = ipar[10];
    const double mu_lam = mem_intensity * hop_lambda;
    if (max_steps <= 0)
        max_steps = INT64_MAX;

    int rc = -1;
    rk_state rng;
    rk_seed(&rng, seed);

    int64_t *pending = (int64_t *)calloc((size_t)n_tasks, sizeof(int64_t));
    int64_t *exec_node = (int64_t *)calloc((size_t)n_tasks, sizeof(int64_t));
    uint8_t *phase = (uint8_t *)calloc((size_t)n_tasks, 1);
    int64_t *order = (int64_t *)malloc((size_t)(T > 1 ? T : 1) * sizeof(int64_t));
    int64_t *uidx = (int64_t *)malloc((size_t)(T > 1 ? T : 1) * sizeof(int64_t));
    double *dl_free = (double *)calloc((size_t)T, sizeof(double));
    ring_t *local = (ring_t *)calloc((size_t)T, sizeof(ring_t));
    int64_t *wcur = (int64_t *)malloc((size_t)T * sizeof(int64_t));
    if (!pending || !exec_node || !phase || !order || !uidx || !dl_free ||
        !local || !wcur)
        goto fail1;
    if (has_faults)
        for (int64_t i = 0; i < T; i++)
            wcur[i] = fwoff[i];
    for (int64_t i = 0; i < T; i++)
        if (ring_init(&local[i], 256)) goto fail1;
    ring_t shared;
    if (ring_init(&shared, 1024)) goto fail1;
    heap_t evq;
    if (heap_init(&evq, (size_t)(2 * T + 8))) goto fail2;
    pyset_t parked;
    if (pyset_init(&parked)) goto fail3;

    double sl_free = 0.0, sl_waited = 0.0;
    double remote = 0.0, total_exec = 0.0, makespan = 0.0;
    int64_t steals = 0, failed = 0, live = 1;
    int64_t reclaimed = 0, reexec = 0, executed = 0, steps = 0, status = 0;
    double fault_lost = 0.0, last_t = 0.0;
    uint64_t seq = 0;
    fault_env_t fenv = {&evq, &parked, local, &shared, fwend,
                        wake_latency, depth_first, &seq, &reclaimed};

    /* ignition: master runs the root, workers go hunting */
    seq++; if (heap_push(&evq, 0.0, seq, 0, 0)) goto fail4;
    for (int64_t th = 1; th < T; th++) {
        seq++;
        if (heap_push(&evq, 0.0, seq, (int32_t)th, -1)) goto fail4;
    }

    while (evq.len) {
        ev_t ev = heap_pop(&evq);
        double t = ev.t;
        int64_t th = ev.th;
        int64_t task = ev.task;

        if (++steps > max_steps) {
            status = 1;
            last_t = t;
            break;
        }
        if (has_faults) {
            int64_t c = wcur[th];
            const int64_t lim = fwoff[th + 1];
            while (c < lim && fwend[c] <= t)
                c++;
            wcur[th] = c;
            if (c < lim && fwstart[c] <= t) {
                if (go_offline(&fenv, t, th, task, c)) goto fail4;
                continue;
            }
        }

        if (task < 0) {
            /* ---- acquire: local pop / steal sweep / shared FIFO ---- */
            if (depth_first) {
                ring_t *lp = &local[th];
                if (lp->len) {
                    task = ring_pop_back(lp);
                    if (rdn < 0)
                        t += qop_time;
                    else
                        t += qop_time * (1.0 + hop_lambda_steal *
                             (double)node_dist[core_node[cores[th]] * NN + rdn]);
                } else {
                    /* materialize one sweep from the compiled plan */
                    int64_t n_order = 0;
                    for (int64_t g = vp_group_off[th];
                         g < vp_group_off[th + 1]; g++) {
                        const int64_t u0 = vp_unit_off[g];
                        const int64_t u1 = vp_unit_off[g + 1];
                        const int64_t nu = u1 - u0;
                        if (nu > 1) {
                            for (int64_t k = 0; k < nu; k++)
                                uidx[k] = u0 + k;
                            rk_shuffle(&rng, uidx, nu);
                            for (int64_t k = 0; k < nu; k++)
                                for (int64_t j = vp_victim_off[uidx[k]];
                                     j < vp_victim_off[uidx[k] + 1]; j++)
                                    order[n_order++] = vp_victims[j];
                        } else {
                            for (int64_t j = vp_victim_off[u0];
                                 j < vp_victim_off[u1]; j++)
                                order[n_order++] = vp_victims[j];
                        }
                    }
                    task = -1;
                    const int64_t tn = core_node[cores[th]];
                    for (int64_t k = 0; k < n_order; k++) {
                        int64_t v = order[k];
                        double d = (rdn < 0)
                            ? (double)node_dist[tn * NN + core_node[cores[v]]]
                            : (double)node_dist[tn * NN + rdn];
                        t += steal_time * (1.0 + hop_lambda_steal * d);
                        ring_t *lv = &local[v];
                        if (lv->len) {
                            double start = t > dl_free[v] ? t : dl_free[v];
                            t = start + deque_lock_time;
                            dl_free[v] = t;
                            steals++;
                            task = ring_pop_front(lv);
                            break;
                        }
                        failed++;
                    }
                    if (task < 0) {
                        if (live > 0 && pyset_add(&parked, th)) goto fail4;
                        continue;
                    }
                }
            } else {
                /* breadth-first shared FIFO behind one lock */
                if (!shared.len) {
                    if (live > 0 && pyset_add(&parked, th)) goto fail4;
                    continue;
                }
                double start = t > sl_free ? t : sl_free;
                sl_waited += start - t;
                t = start + lock_time;
                sl_free = t;
                if (!shared.len) {
                    if (live > 0 && pyset_add(&parked, th)) goto fail4;
                    continue;
                }
                task = ring_pop_front(&shared);
            }
        }

        /* ---- run `task` on thread th at time t ---- */
        if (migration_rate > 0.0 && rk_double(&rng) < migration_rate) {
            /* randint(1) is special-cased by numpy: no draw consumed */
            cores[th] = (num_cores > 1)
                ? (int64_t)rk_interval(&rng, (uint32_t)(num_cores - 1)) : 0;
            t += cache_refill;
        }
        const int64_t core = cores[th];
        const int64_t n = core_node[core];
        exec_node[task] = n;
        const int64_t pr = par[task];
        const int64_t pn = pr >= 0 ? exec_node[pr] : rnode0;
        double pen = mu_lam * (fr[task] * root_dist[n] +
                               fp[task] * (double)node_dist[n * NN + pn]);
        double w = wp[task];
        double cost = w * (1.0 + pen);
        if (has_faults) {
            cost = cost * fspeed[core];
            int64_t c = wcur[th];
            const int64_t lim = fwoff[th + 1];
            /* t advanced during acquire (probes, locks): windows may
             * have closed — or opened — since the top-of-loop check. */
            while (c < lim && fwend[c] <= t)
                c++;
            wcur[th] = c;
            if (c < lim && fwstart[c] < t + cost) {
                /* preempted/killed mid-execution: partial work is lost
                 * and the task re-executes */
                double s = fwstart[c];
                if (s < t)
                    s = t;
                fault_lost += s - t;
                reexec++;
                if (go_offline(&fenv, s, th, task, c)) goto fail4;
                continue;
            }
        }
        remote += w * pen;
        total_exec += cost;
        t += cost;
        executed++;

        const int64_t nk = nc[task];
        if (nk) {
            const int64_t base = fc[task];
            pending[task] = nk;
            live += nk;
            t += spawn_time * (double)nk;
            double qc = (rdn < 0) ? qop_time
                : qop_time * (1.0 + hop_lambda_steal *
                              (double)node_dist[n * NN + rdn]);
            if (wf_like) {
                /* dive into first child; queue the rest newest-first */
                ring_t *lp = &local[th];
                for (int64_t k = base + nk - 1; k > base; k--) {
                    t += qc;
                    if (ring_push_back(lp, k)) goto fail4;
                    if (parked.used) {
                        seq++;
                        if (heap_push(&evq, t + wake_latency, seq,
                                      (int32_t)pyset_pop(&parked), -1))
                            goto fail4;
                    }
                }
                seq++;
                if (heap_push(&evq, t, seq, (int32_t)th, base)) goto fail4;
                continue;
            }
            if (depth_first) { /* cilk: queue all, re-acquire own front */
                ring_t *lp = &local[th];
                for (int64_t k = base + nk - 1; k >= base; k--) {
                    t += qc;
                    if (ring_push_back(lp, k)) goto fail4;
                    if (parked.used) {
                        seq++;
                        if (heap_push(&evq, t + wake_latency, seq,
                                      (int32_t)pyset_pop(&parked), -1))
                            goto fail4;
                    }
                }
            } else { /* bf: shared FIFO in spawn order */
                for (int64_t k = base; k < base + nk; k++) {
                    double start = t > sl_free ? t : sl_free;
                    sl_waited += start - t;
                    t = start + lock_time;
                    sl_free = t;
                    if (ring_push_back(&shared, k)) goto fail4;
                    if (parked.used) {
                        seq++;
                        if (heap_push(&evq, t + wake_latency, seq,
                                      (int32_t)pyset_pop(&parked), -1))
                            goto fail4;
                    }
                }
            }
            seq++;
            if (heap_push(&evq, t, seq, (int32_t)th, -1)) goto fail4;
            continue;
        }

        /* ---- leaf: propagate completion up the tree ---- */
        live--;
        int64_t node = task;
        while (1) {
            int64_t parent = par[node];
            if (parent < 0)
                break;
            int64_t pd = --pending[parent];
            if (pd > 0)
                break;
            if (phase[parent] == 0 && npw[parent]) {
                /* taskwait passed: spawn the parallel combine wave */
                phase[parent] = 1;
                int64_t k = npw[parent];
                int64_t fp0 = fpw[parent];
                pending[parent] = k;
                live += k;
                t += spawn_time * (double)k;
                if (depth_first) {
                    double qc = (rdn < 0) ? qop_time
                        : qop_time * (1.0 + hop_lambda_steal *
                                      (double)node_dist[core_node[cores[th]] * NN + rdn]);
                    ring_t *lp = &local[th];
                    for (int64_t j = fp0 + k - 1; j >= fp0; j--) {
                        t += qc;
                        if (ring_push_back(lp, j)) goto fail4;
                        if (parked.used) {
                            seq++;
                            if (heap_push(&evq, t + wake_latency, seq,
                                          (int32_t)pyset_pop(&parked), -1))
                                goto fail4;
                        }
                    }
                } else {
                    for (int64_t j = fp0 + k - 1; j >= fp0; j--) {
                        double start = t > sl_free ? t : sl_free;
                        sl_waited += start - t;
                        t = start + lock_time;
                        sl_free = t;
                        if (ring_push_back(&shared, j)) goto fail4;
                        if (parked.used) {
                            seq++;
                            if (heap_push(&evq, t + wake_latency, seq,
                                          (int32_t)pyset_pop(&parked), -1))
                                goto fail4;
                        }
                    }
                }
                break;
            }
            double w2 = wpo[parent];
            if (w2 > 0.0) {
                /* join continuation with the parent's locality profile */
                int64_t pn2 = exec_node[parent];
                double pen2 = mu_lam * (fr[parent] * root_dist[n] +
                                        fp[parent] * (double)node_dist[n * NN + pn2]);
                double c2 = w2 * (1.0 + pen2);
                if (has_faults)
                    c2 = c2 * fspeed[core];
                remote += w2 * pen2;
                total_exec += c2;
                t += c2;
            }
            node = parent;
        }
        if (t > makespan)
            makespan = t;
        seq++;
        if (heap_push(&evq, t, seq, (int32_t)th, -1)) goto fail4;
    }

    if (status == 0 && executed != n_tasks)
        status = 2;             /* loop drained with work stranded */
    if (status != 1)
        last_t = makespan;
    dout[0] = makespan;
    dout[1] = remote;
    dout[2] = total_exec;
    dout[3] = sl_waited;
    dout[4] = fault_lost;
    dout[5] = last_t;
    iout[0] = steals;
    iout[1] = failed;
    iout[2] = reclaimed;
    iout[3] = reexec;
    iout[4] = executed;
    iout[5] = steps;
    iout[6] = status;
    rc = 0;

fail4:
    pyset_free(&parked);
fail3:
    free(evq.e);
fail2:
    free(shared.buf);
fail1:
    if (local)
        for (int64_t i = 0; i < T; i++)
            free(local[i].buf);
    free(wcur);
    free(local); free(dl_free); free(uidx); free(order);
    free(phase); free(exec_node); free(pending);
    return rc;
}

/* ------------------------------------------------------------------ */
/* Batched sweep entry — multi-threaded cell dispatch                 */
/* ------------------------------------------------------------------ */

/* Every per-config argument arrives as an array of pointers (one per
 * config, same order as the sim_run parameters). Workers pull cell
 * indices from an atomic counter; each sim_run call is self-contained
 * (private heap/queues/rng on the worker's stack+heap, no globals) and
 * writes to its own dout/iout/rc slot, so results are ordered and
 * bit-identical to the serial loop regardless of worker count. */

typedef struct {
    int64_t n_cfg;
    void **a[23];        /* the 23 per-config pointer tables, in order */
    double *dout;        /* 6 slots per config */
    int64_t *iout;       /* 7 slots per config */
    int64_t *rc;         /* per-config sim_run return code */
    volatile int64_t next;
} batch_t;

static void batch_run_one(batch_t *b, int64_t i)
{
    void **const *a = b->a;
    b->rc[i] = (int64_t)sim_run(
        (const double *)a[0][i], (const int64_t *)a[1][i],
        (const double *)a[2][i], (const double *)a[3][i],
        (const double *)a[4][i], (const double *)a[5][i],
        (const int64_t *)a[6][i], (const int64_t *)a[7][i],
        (const int64_t *)a[8][i], (const int64_t *)a[9][i],
        (const int64_t *)a[10][i],
        (const int64_t *)a[11][i], (const int64_t *)a[12][i],
        (const double *)a[13][i],
        (int64_t *)a[14][i],
        (const int64_t *)a[15][i], (const int64_t *)a[16][i],
        (const int64_t *)a[17][i], (const int64_t *)a[18][i],
        (const double *)a[19][i], (const int64_t *)a[20][i],
        (const double *)a[21][i], (const double *)a[22][i],
        b->dout + 6 * i, b->iout + 7 * i);
}

#ifndef CSIM_NO_THREADS
static void *batch_worker(void *arg)
{
    batch_t *b = (batch_t *)arg;
    for (;;) {
        int64_t i = __sync_fetch_and_add(&b->next, 1);
        if (i >= b->n_cfg)
            break;
        batch_run_one(b, i);
    }
    return NULL;
}
#endif

/* 1 when the library was built with the pthread worker pool. */
int sim_threads_available(void)
{
#ifdef CSIM_NO_THREADS
    return 0;
#else
    return 1;
#endif
}

/* Run n_cfg prepared configs on n_workers threads (n_workers <= 1, a
 * single config, or a -DCSIM_NO_THREADS build: the serial loop, exactly
 * the pre-pool code path). rc_out[i] receives each config's sim_run
 * return code (0 ok, negative = allocation failure); failing configs do
 * not stop the rest of the batch. Returns the number of failed configs.
 */
int64_t sim_run_batch(int64_t n_cfg, int64_t n_workers,
                      void **dpar, void **ipar,
                      void **wp, void **wpo, void **fr, void **fp,
                      void **fc, void **nc, void **fpw, void **npw,
                      void **par,
                      void **core_node, void **node_dist, void **root_dist,
                      void **cores,
                      void **vp_group_off, void **vp_unit_off,
                      void **vp_victim_off, void **vp_victims,
                      void **fspeed, void **fwoff,
                      void **fwstart, void **fwend,
                      double *dout, int64_t *iout, int64_t *rc_out)
{
    batch_t b;
    b.n_cfg = n_cfg;
    b.a[0] = dpar; b.a[1] = ipar; b.a[2] = wp; b.a[3] = wpo;
    b.a[4] = fr; b.a[5] = fp; b.a[6] = fc; b.a[7] = nc;
    b.a[8] = fpw; b.a[9] = npw; b.a[10] = par;
    b.a[11] = core_node; b.a[12] = node_dist; b.a[13] = root_dist;
    b.a[14] = cores;
    b.a[15] = vp_group_off; b.a[16] = vp_unit_off;
    b.a[17] = vp_victim_off; b.a[18] = vp_victims;
    b.a[19] = fspeed; b.a[20] = fwoff;
    b.a[21] = fwstart; b.a[22] = fwend;
    b.dout = dout;
    b.iout = iout;
    b.rc = rc_out;
    b.next = 0;

    if (n_workers > n_cfg)
        n_workers = n_cfg;
#ifndef CSIM_NO_THREADS
    if (n_workers > 1) {
        if (n_workers > 1024)
            n_workers = 1024;
        pthread_t *tids = (pthread_t *)malloc((size_t)(n_workers - 1)
                                              * sizeof(pthread_t));
        int64_t spawned = 0;
        if (tids) {
            for (int64_t k = 0; k < n_workers - 1; k++)
                if (pthread_create(&tids[spawned], NULL,
                                   batch_worker, &b) == 0)
                    spawned++;
        }
        /* the calling thread is worker 0; a partially (or fully)
         * failed spawn just means fewer helpers — the atomic counter
         * still drains every cell */
        batch_worker(&b);
        for (int64_t k = 0; k < spawned; k++)
            pthread_join(tids[k], NULL);
        free(tids);
    } else
#endif
    {
        for (int64_t i = 0; i < n_cfg; i++)
            batch_run_one(&b, i);
    }

    int64_t nfail = 0;
    for (int64_t i = 0; i < n_cfg; i++)
        if (rc_out[i] != 0)
            nfail++;
    return nfail;
}

/* ------------------------------------------------------------------ */
/* Self-test hooks (used by the test suite to fuzz the replicas)      */
/* ------------------------------------------------------------------ */

/* Raw MT draws, to compare against numpy's randint(0, 2**32, uint32). */
void mt_selftest(uint32_t seed, int64_t n, uint32_t *out)
{
    rk_state st;
    rk_seed(&st, seed);
    for (int64_t i = 0; i < n; i++)
        out[i] = rk_random(&st);
}

/* Shuffle replica: shuffles arange(n) repeatedly, writing each result. */
void shuffle_selftest(uint32_t seed, int64_t n, int64_t reps, int64_t *out)
{
    rk_state st;
    rk_seed(&st, seed);
    for (int64_t r = 0; r < reps; r++) {
        int64_t *row = out + r * n;
        for (int64_t i = 0; i < n; i++)
            row[i] = i;
        rk_shuffle(&st, row, n);
    }
}

/* Set replica: ops[i] >= 0 -> add(ops[i]); ops[i] == -1 -> pop.
 * Popped values are appended to out; returns number of pops. */
int64_t set_selftest(int64_t nops, const int64_t *ops, int64_t *out)
{
    pyset_t s;
    if (pyset_init(&s))
        return -1;
    int64_t npop = 0;
    for (int64_t i = 0; i < nops; i++) {
        if (ops[i] >= 0) {
            if (pyset_add(&s, ops[i])) { pyset_free(&s); return -1; }
        } else if (s.used) {
            out[npop++] = pyset_pop(&s);
        }
    }
    pyset_free(&s);
    return npop;
}
