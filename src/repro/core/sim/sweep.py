"""Batched simulation sweeps.

The paper-reproduction drivers run *grids*: every figure is a cartesian
sweep over (topology, thread binding, workload, scheduler, data
placement, seed). Calling :func:`~.runtime.simulate` per cell re-enters
the Python↔engine boundary a few hundred times; a :class:`SweepPlan`
instead prepares every config up front — sharing the compiled task
tables (cached on the workload), victim plans and root-distance vectors
(cached on the topology), and serial-time references (cached on the
table) — and hands the whole batch to the engine in one call. On the C
path that is a single ``sim_run_batch`` invocation: the kernel iterates
configs back to back without re-crossing into Python per run.

All of those per-cell compile products also persist across *processes*
through the one shared :func:`~.compile_cache.get_cache` handle — a
re-run grid mmaps its tables, replays its serial references, and loads
its victim plans from disk before the first cell simulates.

Results are bit-identical to the per-call loop: each config gets its own
``RandomState(seed)`` stream and the engines are untouched — batching
changes *when* work is dispatched, never *what* runs.

Configs are validated at :meth:`SweepPlan.add` time — an unknown
scheduler, a core outside the topology, or a bad spill node fails
immediately with the offending grid cell named, instead of surfacing
hundreds of configs later inside the C kernel.

Every config lowers to an immutable :class:`~.context.ExecContext`
before running; :meth:`SweepPlan.add_context` takes one directly (the
:class:`~.machine.Machine` facade builds plans this way), while
:meth:`SweepPlan.add` keeps the legacy ``simulate()`` argument tuple.

Example::

    plan = SweepPlan()
    for T in (2, 4, 8, 16):
        for sched in ("wf", "dfwspt", "dfwsrpt"):
            plan.add(topo, priority.allocate_threads(topo, T), wl, sched,
                     root_data_nodes=spill, serial_reference=serial)
    results = plan.run()        # list[SimResult], one per add() order

or, declaratively (one call per paper figure)::

    Machine(topo).grid(workloads=[wl], schedulers=("wf", "dfwsrpt"),
                       threads=(2, 4, 8, 16), placements=("spill:2",))
"""

from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Optional, Sequence

import numpy as np

from . import _csim, _engine_py, policy
from .context import ExecContext
from .runtime import (SimParams, SimResult, SimStalled, Workload,
                      _finish_result, _prepare_ctx, _select_engine,
                      resolve_timeout, resolve_workers, serial_time)

__all__ = ["SweepConfig", "SweepPlan", "CellError", "CellTimeout",
           "WorkerDied", "RetryPolicy", "run_sweep",
           "Stat", "CellStats", "aggregate"]


class CellTimeout(RuntimeError):
    """A cell exceeded its wall-clock budget; its worker was killed.

    Raised (or recorded, under ``strict=False``) by the supervised
    batch path — this is the *wall-clock* complement of the step
    watchdog: the watchdog catches a sim-logic stall inside a running
    loop, the timeout catches a wedged C call or a loop that makes
    steps too slowly to ever trip it.
    """

    def __init__(self, timeout: float, engine: str):
        self.timeout = timeout
        self.engine = engine
        super().__init__(
            f"cell exceeded the {timeout:g}s wall-clock timeout on the "
            f"{engine!r} engine; worker killed")


class WorkerDied(RuntimeError):
    """A pool worker vanished mid-cell (SIGKILL, OOM-kill, segfault).

    The supervisor respawned the worker; the cell's fate follows the
    retry policy (the default re-attempts it — death is transient).
    """

    def __init__(self, engine: str):
        self.engine = engine
        super().__init__(
            f"worker process died mid-cell on the {engine!r} engine "
            "(killed or crashed); worker respawned")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/degradation policy for transient cell failures.

    A failed cell gets up to ``retries`` re-attempts beyond the first,
    with ``backoff * 2**k`` seconds of sleep before retry round ``k``
    (capped at ``max_backoff``) — transient causes (memory pressure,
    a killed worker) benefit from yielding the machine briefly. With
    ``degrade=True`` a cell whose C-engine attempt failed transiently
    re-runs on the pure-Python engine (bit-identical results, no native
    allocation, kill-safe), implementing the C → py → recorded-failure
    ladder. Deterministic failures (:class:`~.runtime.SimStalled`, bad
    configs, engine exceptions like ``ValueError``) are never retried —
    they would fail identically every time.
    """
    retries: int = 2
    backoff: float = 0.25
    max_backoff: float = 4.0
    degrade: bool = True


# Failure types worth re-attempting: environmental, not deterministic.
_TRANSIENT = (MemoryError, OSError, EOFError, CellTimeout, WorkerDied)


@dataclasses.dataclass
class CellError:
    """A failed sweep cell under ``strict=False``: the grid label of the
    offending config plus the error it raised. Takes the cell's slot in
    the result list so the add()-order ↔ result mapping survives.

    Parallel/durable paths add provenance: ``engine`` is the engine the
    final attempt ran on, ``attempts`` records every attempt as
    ``(engine, "ErrType: message")`` when the retry supervisor was
    engaged, and ``traceback`` carries the failing worker's formatted
    remote stack when the cell died inside a pool process.
    """
    label: str
    index: int
    error: Exception
    engine: str = ""
    attempts: "tuple[tuple[str, str], ...]" = ()
    traceback: str = ""

    def __repr__(self) -> str:
        via = f" [{self.engine}]" if self.engine else ""
        if len(self.attempts) > 1:
            trail = " -> ".join(f"{e}: {m.split(':')[0]}"
                                for e, m in self.attempts)
            via = f" [{len(self.attempts)} attempts: {trail}]"
        return (f"CellError({self.label!r}{via}: "
                f"{type(self.error).__name__}: {self.error})")


@dataclasses.dataclass(frozen=True, eq=False)
class SweepConfig:
    """One cell of a sweep grid — the ``simulate()`` argument tuple.

    ``context``, when set, is the pre-compiled :class:`ExecContext` the
    cell runs under (the raw fields then mirror its lowered values);
    otherwise one is derived from the raw fields at run time.
    """
    topo: object
    thread_cores: tuple
    workload: Workload
    scheduler: object            # registered name or SchedulerSpec
    params: Optional[SimParams] = None
    seed: int = 0
    root_data_nodes: object = None
    runtime_data_node: Optional[int] = None
    migration_rate: float = 0.0
    serial_reference: Optional[float] = None
    context: Optional[ExecContext] = None
    label: Optional[str] = None  # grid-cell display name for errors

    def to_context(self) -> ExecContext:
        """The :class:`ExecContext` this cell runs under."""
        if self.context is not None:
            return self.context
        return ExecContext.from_raw(
            self.topo, self.params or SimParams(), self.thread_cores,
            self.root_data_nodes, self.runtime_data_node,
            self.migration_rate)

    def validate(self, cell: str = "sweep config") -> None:
        """Raise ``ValueError`` naming ``cell`` on any bad field."""
        def bad(msg):
            raise ValueError(f"{cell}: {msg}")

        try:
            policy.get_spec(self.scheduler)
        except ValueError as e:
            bad(e)
        topo = self.topo
        cores = self.thread_cores
        if not cores:
            bad("empty thread binding")
        outside = [c for c in cores if not 0 <= int(c) < topo.num_cores]
        if outside:
            bad(f"cores {outside} outside topology "
                f"({topo.num_cores} cores)")
        if len(set(cores)) != len(cores):
            bad(f"duplicate cores in binding {cores}")
        nodes = self.root_data_nodes
        if nodes is not None:
            if isinstance(nodes, (int, np.integer)):
                nodes = (int(nodes),)
            outside = [n for n in nodes if not 0 <= int(n) < topo.num_nodes]
            if outside:
                bad(f"root data nodes {outside} outside topology "
                    f"({topo.num_nodes} nodes)")
        rt = self.runtime_data_node
        if rt is not None and not 0 <= int(rt) < topo.num_nodes:
            bad(f"runtime_data_node {rt} outside topology "
                f"({topo.num_nodes} nodes)")
        if not 0.0 <= self.migration_rate <= 1.0:
            bad(f"migration_rate {self.migration_rate} outside [0, 1]")
        if self.params is not None and not isinstance(self.params,
                                                      SimParams):
            bad(f"params is {type(self.params).__name__}, not SimParams")


class SweepPlan:
    """An ordered batch of :class:`SweepConfig`; results match add() order."""

    def __init__(self, configs: Sequence[SweepConfig] = ()):
        self.configs: list[SweepConfig] = list(configs)

    def _cell_name(self, workload, scheduler, T) -> str:
        sched = scheduler.name if hasattr(scheduler, "name") else scheduler
        return (f"sweep cell #{len(self.configs)} "
                f"({workload.name}/{sched}/T={T})")

    def add(self, topo, thread_cores, workload, scheduler, *,
            errors: "list | None" = None, **kwargs) -> "SweepConfig | None":
        """Append one cell from ``simulate()``-style arguments.

        Validates eagerly: a bad scheduler name, core id, or data node
        raises here — naming this grid cell — not mid-batch in the
        engine. Pass ``errors=[...]`` to *collect* the failure message
        instead of raising (the cell is skipped, ``None`` returned) —
        grid expansions use this to report every offending cell in one
        error instead of failing fast on the first.
        """
        cfg = SweepConfig(topo, tuple(int(c) for c in thread_cores),
                          workload, scheduler, **kwargs)
        cell = cfg.label or self._cell_name(workload, scheduler,
                                            len(cfg.thread_cores))
        try:
            cfg.validate(cell)
        except ValueError as e:
            if errors is None:
                raise
            errors.append(str(e))
            return None
        self.configs.append(cfg)
        return cfg

    def add_context(self, context: ExecContext, workload, scheduler, *,
                    seed: int = 0,
                    serial_reference: Optional[float] = None,
                    label: Optional[str] = None,
                    errors: "list | None" = None) -> "SweepConfig | None":
        """Append one cell running under a compiled :class:`ExecContext`.

        Only the scheduler needs checking here — the context itself was
        validated when :meth:`ExecContext.compile` lowered it. With
        ``errors=[...]`` a failure is collected instead of raised and
        the cell skipped (see :meth:`add`).
        """
        try:
            policy.get_spec(scheduler)
        except ValueError as e:
            cell = label or self._cell_name(workload, scheduler,
                                            context.threads)
            if errors is None:
                raise ValueError(f"{cell}: {e}") from None
            errors.append(f"{cell}: {e}")
            return None
        cfg = SweepConfig(context.topo, context.thread_cores, workload,
                          scheduler, params=context.params, seed=seed,
                          root_data_nodes=context.root_data_nodes,
                          runtime_data_node=context.runtime_data_node,
                          migration_rate=context.migration_rate,
                          serial_reference=serial_reference,
                          context=context, label=label)
        self.configs.append(cfg)
        return cfg

    def __len__(self) -> int:
        return len(self.configs)

    def __iter__(self):
        return iter(self.configs)

    def run(self, strict: bool = True, workers: "int | None" = None,
            *, store=None, timeout: "float | None" = None,
            retry: "RetryPolicy | None" = None
            ) -> "list[SimResult | CellError]":
        return run_sweep(self, strict=strict, workers=workers,
                         store=store, timeout=timeout, retry=retry)


def _cell_label(cfg: SweepConfig, i: int) -> str:
    if cfg.label:
        return cfg.label
    sched = cfg.scheduler.name if hasattr(cfg.scheduler, "name") \
        else cfg.scheduler
    return (f"sweep cell #{i} ({cfg.workload.name}/{sched}/"
            f"T={len(cfg.thread_cores)})")


def run_sweep(plan: "SweepPlan | Sequence[SweepConfig]",
              strict: bool = True,
              workers: "int | None" = None,
              *, store=None,
              timeout: "float | None" = None,
              retry: "RetryPolicy | None" = None
              ) -> "list[SimResult | CellError]":
    """Run every config in ``plan``; returns results in config order.

    ``workers`` sets how many cells run concurrently — a pthread pool
    inside the C kernel, a fork-based process pool around the Python
    engine. Default (``None``) resolves via :func:`resolve_workers`:
    the first config's ``SimParams.workers``, then ``REPRO_SIM_WORKERS``,
    then ``os.cpu_count()``. Each cell runs on its own per-(cell, seed)
    RNG stream into its own result slot, so results are bit-identical
    to ``workers=1`` at any worker count.

    Per-cell error isolation: under ``strict=False`` a failing cell —
    bad config lowering, an engine failure inside a C worker or a py
    subprocess, or a :class:`SimStalled` watchdog trip — becomes a
    :class:`CellError` naming its grid label in that cell's result
    slot, and the rest of the batch still runs. Under ``strict=True``
    (default) the first failure raises, with the cell label attached
    (``SimStalled.cell`` for stalls).

    Durable execution (all opt-in, golden paths untouched):

    * ``store`` — a :class:`~.store.ResultStore` (or a journal path):
      cells whose :func:`~.store.cell_key` is already journaled are
      *replayed* from the store — no context preparation, no engine
      call — and every newly completed cell is committed before the
      run returns. A fully warm store answers the whole sweep without
      selecting an engine at all. Only successes are journaled;
      failures are re-attempted on the next run.
    * ``timeout`` — per-cell wall-clock seconds (default: the
      ``REPRO_SIM_TIMEOUT`` env var). Batches then run on the
      supervised fork pool — even for the C engine, whose ``run`` is
      called inside the killable worker — so a cell that overruns is
      killed, recorded as a :class:`CellTimeout`, and its siblings
      keep running.
    * ``retry`` — a :class:`RetryPolicy`: transient failures (memory
      pressure, a killed/died worker, a timeout) are re-attempted with
      capped exponential backoff, degrading C → py before recording a
      failure. Deterministic failures never retry.
    """
    configs = list(plan.configs if isinstance(plan, SweepPlan) else plan)
    if not configs:
        return []
    if store is not None and not hasattr(store, "get"):
        from .store import ResultStore
        store = ResultStore(os.fspath(store))
    timeout = resolve_timeout(timeout)
    nw = resolve_workers(workers, next(
        (c.params for c in configs if c.params is not None), None))
    n = len(configs)
    results: "list[SimResult | CellError | None]" = [None] * n

    # Pass 1: resolve config → context, satisfy store hits, collect the
    # cells that actually need simulating. No engine is selected (or
    # even required to exist) until a miss demands one.
    pending: list = []           # per-cell mutable descriptor dicts
    for i, cfg in enumerate(configs):
        try:
            spec = policy.get_spec(cfg.scheduler)
            ectx = cfg.to_context()
            if cfg.serial_reference is not None:
                serial = cfg.serial_reference
            else:
                # identical to the value derived from a prepared ctx:
                # serial_time normalizes root_data_nodes the same way
                serial = serial_time(ectx.topo, cfg.workload,
                                     ectx.thread_cores[0],
                                     ectx.root_data_nodes, ectx.params)
            key = None
            if store is not None:
                from .store import cell_key
                key = cell_key(ectx, cfg.workload, spec, cfg.seed, serial)
                hit = store.get(key)
                if hit is not None:
                    results[i] = hit
                    continue
        except Exception as e:
            if strict:
                raise
            results[i] = CellError(_cell_label(cfg, i), i, e)
            continue
        pending.append(dict(i=i, cfg=cfg, spec=spec, ectx=ectx,
                            serial=serial, key=key, attempts=[]))

    if not pending:
        return results           # fully warm store: engines never ran

    engine0 = _select_engine()
    for cell in pending:
        cell["engine"] = engine0
    max_attempts = 1 + (retry.retries if retry is not None else 0)

    def record_failure(cell, err, eng):
        i, cfg = cell["i"], cell["cfg"]
        cell["attempts"].append((eng, f"{type(err).__name__}: {err}"))
        transient = isinstance(err, _TRANSIENT)
        if transient and len(cell["attempts"]) < max_attempts:
            if retry is not None and retry.degrade and eng == "c":
                cell["engine"] = "py"
            return cell          # re-attempt next round
        label = _cell_label(cfg, i)
        if isinstance(err, SimStalled):
            err = err.with_cell(label)
            label = err.cell
        if strict:
            raise err
        results[i] = CellError(
            label, i, err, engine=eng,
            attempts=tuple(cell["attempts"]),
            traceback=getattr(err, "remote_traceback", ""))
        return None

    round_no = 0
    while pending:
        if round_no > 0 and retry is not None and retry.backoff > 0:
            time.sleep(min(retry.backoff * (2 ** (round_no - 1)),
                           retry.max_backoff))
        round_no += 1
        by_engine: dict = {}
        for cell in pending:
            by_engine.setdefault(cell["engine"], []).append(cell)
        pending = []
        for eng, cells in sorted(by_engine.items()):
            # contexts are prepared fresh every round: a failed attempt
            # consumed its rng stream and may have migrated its cores
            prepared = []
            for c in cells:
                try:
                    prepared.append(_prepare_ctx(c["ectx"],
                                                 c["cfg"].workload,
                                                 c["spec"], c["cfg"].seed))
                except Exception as e:
                    prepared.append(None)
                    nxt = record_failure(c, e, eng)
                    if nxt is not None:
                        pending.append(nxt)
            cells = [c for c, ctx in zip(cells, prepared) if ctx is not None]
            ctxs = [ctx for ctx in prepared if ctx is not None]
            if not ctxs:
                continue
            if timeout is not None:
                # process-level supervision even for the C engine: its
                # run() is called inside a killable fork worker
                run_fn = _csim.run if eng == "c" else _engine_py.run
                tagged = _engine_py.run_supervised(ctxs, nw, timeout,
                                                   run_fn)
            else:
                batch = _csim.run_batch if eng == "c" \
                    else _engine_py.run_batch
                tagged = [("err", o) if isinstance(o, Exception)
                          else ("ok", o)
                          for o in batch(ctxs, workers=nw)]
            for cell, ctx, out in zip(cells, ctxs, tagged):
                kind = out[0]
                if kind == "ok":
                    try:
                        res = _finish_result(ctx, out[1], cell["serial"],
                                             eng)
                    except SimStalled as e:
                        # deterministic: the same stall reproduces on
                        # every attempt, so it is never retried
                        nxt = record_failure(cell, e, eng)
                        assert nxt is None
                        continue
                    if store is not None:
                        store.put(cell["key"], res)
                    results[cell["i"]] = res
                    continue
                if kind == "err":
                    err = out[1]
                elif kind == "timeout":
                    err = CellTimeout(out[1], eng)
                else:            # "died"
                    err = WorkerDied(eng)
                nxt = record_failure(cell, err, eng)
                if nxt is not None:
                    pending.append(nxt)
    return results


# ------------------------------------------------------------------ #
# Monte-Carlo aggregation: per-cell replica statistics               #
# ------------------------------------------------------------------ #

@dataclasses.dataclass(frozen=True)
class Stat:
    """Summary statistics of one metric over Monte-Carlo replicas.

    ``ci95`` is the normal-approximation 95% confidence half-width of
    the mean, ``1.96 * std / sqrt(n)`` (0 for a single replica); report
    values as ``mean ± ci95``. ``std`` is the sample standard deviation
    (ddof=1).
    """
    mean: float
    std: float
    min: float
    max: float
    ci95: float


def _stat(xs: Sequence[float]) -> Stat:
    n = len(xs)
    if n == 0:
        nan = float("nan")
        return Stat(nan, nan, nan, nan, nan)
    mean = math.fsum(xs) / n
    if n > 1:
        var = math.fsum((x - mean) ** 2 for x in xs) / (n - 1)
        std = math.sqrt(var)
    else:
        std = 0.0
    return Stat(mean=mean, std=std, min=min(xs), max=max(xs),
                ci95=1.96 * std / math.sqrt(n))


_CELLSTAT_METRICS = ("makespan", "speedup", "steals", "failed_probes",
                     "remote_work_fraction", "queue_wait", "reclaimed",
                     "reexec", "fault_lost")


@dataclasses.dataclass(frozen=True)
class CellStats:
    """One grid cell's Monte-Carlo replica results, aggregated.

    Every :class:`~.runtime.SimResult` metric gets a :class:`Stat`
    (mean/std/min/max/CI95 over the successful replicas); the raw
    per-seed results stay available in ``results`` (add order) and any
    failed replicas (``strict=False``) in ``errors``. ``n`` counts the
    successful replicas the stats are computed over.
    """
    n: int
    makespan: Stat
    speedup: Stat
    steals: Stat
    failed_probes: Stat
    remote_work_fraction: Stat
    queue_wait: Stat
    reclaimed: Stat
    reexec: Stat
    fault_lost: Stat
    results: "tuple[SimResult, ...]" = ()
    errors: "tuple[CellError, ...]" = ()


def aggregate(results: "Sequence[SimResult | CellError]") -> CellStats:
    """Aggregate one cell's replica results into a :class:`CellStats`."""
    ok = [r for r in results if isinstance(r, SimResult)]
    errs = tuple(r for r in results if isinstance(r, CellError))
    stats = {m: _stat([float(getattr(r, m)) for r in ok])
             for m in _CELLSTAT_METRICS}
    return CellStats(n=len(ok), results=tuple(ok), errors=errs, **stats)
