"""Batched simulation sweeps.

The paper-reproduction drivers run *grids*: every figure is a cartesian
sweep over (topology, thread binding, workload, scheduler, data
placement, seed). Calling :func:`~.runtime.simulate` per cell re-enters
the Python↔engine boundary a few hundred times; a :class:`SweepPlan`
instead prepares every config up front — sharing the compiled task
tables (cached on the workload), victim plans and root-distance vectors
(cached on the topology), and serial-time references (cached on the
table) — and hands the whole batch to the engine in one call. On the C
path that is a single ``sim_run_batch`` invocation: the kernel iterates
configs back to back without re-crossing into Python per run.

Results are bit-identical to the per-call loop: each config gets its own
``RandomState(seed)`` stream and the engines are untouched — batching
changes *when* work is dispatched, never *what* runs.

Example::

    plan = SweepPlan()
    for T in (2, 4, 8, 16):
        for sched in ("wf", "dfwspt", "dfwsrpt"):
            plan.add(topo, priority.allocate_threads(topo, T), wl, sched,
                     root_data_nodes=spill, serial_reference=serial)
    results = plan.run()        # list[SimResult], one per add() order
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from . import _csim, _engine_py, policy
from .runtime import (SimParams, SimResult, Workload, _finish_result,
                      _prepare_ctx, _select_engine, serial_time)

__all__ = ["SweepConfig", "SweepPlan", "run_sweep"]


@dataclasses.dataclass(frozen=True, eq=False)
class SweepConfig:
    """One cell of a sweep grid — the ``simulate()`` argument tuple."""
    topo: object
    thread_cores: tuple
    workload: Workload
    scheduler: object            # registered name or SchedulerSpec
    params: Optional[SimParams] = None
    seed: int = 0
    root_data_nodes: object = None
    runtime_data_node: Optional[int] = None
    migration_rate: float = 0.0
    serial_reference: Optional[float] = None


class SweepPlan:
    """An ordered batch of :class:`SweepConfig`; results match add() order."""

    def __init__(self, configs: Sequence[SweepConfig] = ()):
        self.configs: list[SweepConfig] = list(configs)

    def add(self, topo, thread_cores, workload, scheduler,
            **kwargs) -> SweepConfig:
        cfg = SweepConfig(topo, tuple(int(c) for c in thread_cores),
                          workload, scheduler, **kwargs)
        self.configs.append(cfg)
        return cfg

    def __len__(self) -> int:
        return len(self.configs)

    def __iter__(self):
        return iter(self.configs)

    def run(self) -> list[SimResult]:
        return run_sweep(self)


def run_sweep(plan: "SweepPlan | Sequence[SweepConfig]") -> list[SimResult]:
    """Run every config in ``plan``; returns results in config order."""
    configs = list(plan.configs if isinstance(plan, SweepPlan) else plan)
    if not configs:
        return []
    engine = _select_engine()
    ctxs, serials = [], []
    for cfg in configs:
        spec = policy.get_spec(cfg.scheduler)
        p = cfg.params or SimParams()
        ctx = _prepare_ctx(cfg.topo, cfg.thread_cores, cfg.workload, spec,
                           p, cfg.seed, cfg.root_data_nodes,
                           cfg.runtime_data_node, cfg.migration_rate)
        ctxs.append(ctx)
        if cfg.serial_reference is not None:
            serials.append(cfg.serial_reference)
        else:
            serials.append(serial_time(cfg.topo, cfg.workload,
                                       cfg.thread_cores[0],
                                       ctx["root_data_nodes"], p))
    if engine == "c":
        outs = _csim.run_batch(ctxs)
    else:
        outs = [_engine_py.run(ctx) for ctx in ctxs]
    return [_finish_result(ctx, out, serial, engine)
            for ctx, out, serial in zip(ctxs, outs, serials)]
