"""Batched simulation sweeps.

The paper-reproduction drivers run *grids*: every figure is a cartesian
sweep over (topology, thread binding, workload, scheduler, data
placement, seed). Calling :func:`~.runtime.simulate` per cell re-enters
the Python↔engine boundary a few hundred times; a :class:`SweepPlan`
instead prepares every config up front — sharing the compiled task
tables (cached on the workload), victim plans and root-distance vectors
(cached on the topology), and serial-time references (cached on the
table) — and hands the whole batch to the engine in one call. On the C
path that is a single ``sim_run_batch`` invocation: the kernel iterates
configs back to back without re-crossing into Python per run.

Results are bit-identical to the per-call loop: each config gets its own
``RandomState(seed)`` stream and the engines are untouched — batching
changes *when* work is dispatched, never *what* runs.

Configs are validated at :meth:`SweepPlan.add` time — an unknown
scheduler, a core outside the topology, or a bad spill node fails
immediately with the offending grid cell named, instead of surfacing
hundreds of configs later inside the C kernel.

Every config lowers to an immutable :class:`~.context.ExecContext`
before running; :meth:`SweepPlan.add_context` takes one directly (the
:class:`~.machine.Machine` facade builds plans this way), while
:meth:`SweepPlan.add` keeps the legacy ``simulate()`` argument tuple.

Example::

    plan = SweepPlan()
    for T in (2, 4, 8, 16):
        for sched in ("wf", "dfwspt", "dfwsrpt"):
            plan.add(topo, priority.allocate_threads(topo, T), wl, sched,
                     root_data_nodes=spill, serial_reference=serial)
    results = plan.run()        # list[SimResult], one per add() order

or, declaratively (one call per paper figure)::

    Machine(topo).grid(workloads=[wl], schedulers=("wf", "dfwsrpt"),
                       threads=(2, 4, 8, 16), placements=("spill:2",))
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from . import _csim, _engine_py, policy
from .context import ExecContext
from .runtime import (SimParams, SimResult, Workload, _finish_result,
                      _prepare_ctx, _select_engine, serial_time)

__all__ = ["SweepConfig", "SweepPlan", "run_sweep"]


@dataclasses.dataclass(frozen=True, eq=False)
class SweepConfig:
    """One cell of a sweep grid — the ``simulate()`` argument tuple.

    ``context``, when set, is the pre-compiled :class:`ExecContext` the
    cell runs under (the raw fields then mirror its lowered values);
    otherwise one is derived from the raw fields at run time.
    """
    topo: object
    thread_cores: tuple
    workload: Workload
    scheduler: object            # registered name or SchedulerSpec
    params: Optional[SimParams] = None
    seed: int = 0
    root_data_nodes: object = None
    runtime_data_node: Optional[int] = None
    migration_rate: float = 0.0
    serial_reference: Optional[float] = None
    context: Optional[ExecContext] = None

    def to_context(self) -> ExecContext:
        """The :class:`ExecContext` this cell runs under."""
        if self.context is not None:
            return self.context
        return ExecContext.from_raw(
            self.topo, self.params or SimParams(), self.thread_cores,
            self.root_data_nodes, self.runtime_data_node,
            self.migration_rate)

    def validate(self, cell: str = "sweep config") -> None:
        """Raise ``ValueError`` naming ``cell`` on any bad field."""
        def bad(msg):
            raise ValueError(f"{cell}: {msg}")

        try:
            policy.get_spec(self.scheduler)
        except ValueError as e:
            bad(e)
        topo = self.topo
        cores = self.thread_cores
        if not cores:
            bad("empty thread binding")
        outside = [c for c in cores if not 0 <= int(c) < topo.num_cores]
        if outside:
            bad(f"cores {outside} outside topology "
                f"({topo.num_cores} cores)")
        if len(set(cores)) != len(cores):
            bad(f"duplicate cores in binding {cores}")
        nodes = self.root_data_nodes
        if nodes is not None:
            if isinstance(nodes, (int, np.integer)):
                nodes = (int(nodes),)
            outside = [n for n in nodes if not 0 <= int(n) < topo.num_nodes]
            if outside:
                bad(f"root data nodes {outside} outside topology "
                    f"({topo.num_nodes} nodes)")
        rt = self.runtime_data_node
        if rt is not None and not 0 <= int(rt) < topo.num_nodes:
            bad(f"runtime_data_node {rt} outside topology "
                f"({topo.num_nodes} nodes)")
        if not 0.0 <= self.migration_rate <= 1.0:
            bad(f"migration_rate {self.migration_rate} outside [0, 1]")
        if self.params is not None and not isinstance(self.params,
                                                      SimParams):
            bad(f"params is {type(self.params).__name__}, not SimParams")


class SweepPlan:
    """An ordered batch of :class:`SweepConfig`; results match add() order."""

    def __init__(self, configs: Sequence[SweepConfig] = ()):
        self.configs: list[SweepConfig] = list(configs)

    def _cell_name(self, workload, scheduler, T) -> str:
        sched = scheduler.name if hasattr(scheduler, "name") else scheduler
        return (f"sweep cell #{len(self.configs)} "
                f"({workload.name}/{sched}/T={T})")

    def add(self, topo, thread_cores, workload, scheduler,
            **kwargs) -> SweepConfig:
        """Append one cell from ``simulate()``-style arguments.

        Validates eagerly: a bad scheduler name, core id, or data node
        raises here — naming this grid cell — not mid-batch in the
        engine.
        """
        cfg = SweepConfig(topo, tuple(int(c) for c in thread_cores),
                          workload, scheduler, **kwargs)
        cfg.validate(self._cell_name(workload, scheduler,
                                     len(cfg.thread_cores)))
        self.configs.append(cfg)
        return cfg

    def add_context(self, context: ExecContext, workload, scheduler, *,
                    seed: int = 0,
                    serial_reference: Optional[float] = None) -> SweepConfig:
        """Append one cell running under a compiled :class:`ExecContext`.

        Only the scheduler needs checking here — the context itself was
        validated when :meth:`ExecContext.compile` lowered it.
        """
        try:
            policy.get_spec(scheduler)
        except ValueError as e:
            cell = self._cell_name(workload, scheduler, context.threads)
            raise ValueError(f"{cell}: {e}") from None
        cfg = SweepConfig(context.topo, context.thread_cores, workload,
                          scheduler, params=context.params, seed=seed,
                          root_data_nodes=context.root_data_nodes,
                          runtime_data_node=context.runtime_data_node,
                          migration_rate=context.migration_rate,
                          serial_reference=serial_reference,
                          context=context)
        self.configs.append(cfg)
        return cfg

    def __len__(self) -> int:
        return len(self.configs)

    def __iter__(self):
        return iter(self.configs)

    def run(self) -> list[SimResult]:
        return run_sweep(self)


def run_sweep(plan: "SweepPlan | Sequence[SweepConfig]") -> list[SimResult]:
    """Run every config in ``plan``; returns results in config order."""
    configs = list(plan.configs if isinstance(plan, SweepPlan) else plan)
    if not configs:
        return []
    engine = _select_engine()
    ctxs, serials = [], []
    for cfg in configs:
        spec = policy.get_spec(cfg.scheduler)
        ectx = cfg.to_context()
        ctx = _prepare_ctx(ectx, cfg.workload, spec, cfg.seed)
        ctxs.append(ctx)
        if cfg.serial_reference is not None:
            serials.append(cfg.serial_reference)
        else:
            serials.append(serial_time(ectx.topo, cfg.workload,
                                       ectx.thread_cores[0],
                                       ctx["root_data_nodes"], ectx.params))
    if engine == "c":
        outs = _csim.run_batch(ctxs)
    else:
        outs = [_engine_py.run(ctx) for ctx in ctxs]
    return [_finish_result(ctx, out, serial, engine)
            for ctx, out, serial in zip(ctxs, outs, serials)]
