"""BOTS-like task-DAG generators (paper §V benchmarks).

Each generator reproduces the *tasking structure* of the corresponding
Barcelona OpenMP Task Suite benchmark — recursion shape, fan-out, parallel
combine waves after taskwaits, and memory profile — at a
simulation-friendly scale. Work units are arbitrary (the simulator reports
speedups, which is what the paper reports too).

Memory profiles (``mem_intensity``, ``f_root``, ``f_parent``) follow the
paper's characterization: FFT / Strassen / Sort are "data intensive"
(multi-GB arrays allocated by the master → large first-touch/root traffic)
while NQueens / Floorplan are compute-dominated.
"""

from __future__ import annotations

import numpy as np

from .runtime import TaskSpec, Workload
from .table import table_from_arrays

__all__ = ["fft", "sort", "strassen", "nqueens", "floorplan", "sparselu",
           "fft_flat", "sort_flat", "strassen_flat", "nqueens_flat",
           "sparselu_flat", "WORKLOADS", "make", "workload_cache_key",
           "PAPER_MIN_TASKS"]

# the paper-scale tier targets BOTS-like task counts (FFT medium spawns
# ~10M tasks); anything above this floor exercises the same regimes.
PAPER_MIN_TASKS = 1_000_000


def _wave(total_work: float, chunk: float, f_root: float,
          f_parent: float) -> list[TaskSpec]:
    """A parallel combine wave: ~total_work split into chunk-sized tasks."""
    n = max(1, int(round(total_work / chunk)))
    w = total_work / n
    return [TaskSpec(work_pre=w, f_root=f_root, f_parent=f_parent)
            for _ in range(n)]


def fft(n: int = 1 << 15, cutoff: int = 1 << 4) -> Workload:
    """Cooley-Tukey recursion: two half-size sub-FFTs, then a parallel
    butterfly/twiddle wave (BOTS parallelizes the combine too).

    BOTS FFT (medium) spawns ~10M tasks over ~6 GB of master-allocated
    arrays; the butterfly wave streams the full root array → high f_root.
    Scaled here to ~n/cutoff leaf tasks.
    """
    def rec(m: int) -> TaskSpec:
        if m <= cutoff:
            return TaskSpec(work_pre=m * np.log2(max(m, 2)),
                            f_root=0.75, f_parent=0.25)
        kids = [rec(m // 2), rec(m // 2)]
        post = _wave(1.0 * m, chunk=4.0 * cutoff, f_root=0.8, f_parent=0.2)
        return TaskSpec(work_pre=0.1 * m, work_post=0.05 * m,
                        f_root=0.8, f_parent=0.2,
                        children=kids, post_children=post)
    return Workload("fft", rec(n), mem_intensity=0.9)


def sort(n: int = 1 << 15, cutoff: int = 1 << 4) -> Workload:
    """BOTS sort (cilksort): 4-way split, parallel merge wave after the
    taskwait. 8.5 GB root array (large input) ⇒ data intensive."""
    def rec(m: int) -> TaskSpec:
        if m <= cutoff:
            return TaskSpec(work_pre=m * np.log2(max(m, 2)),
                            f_root=0.7, f_parent=0.3)
        kids = [rec(m // 4) for _ in range(4)]
        post = _wave(1.2 * m, chunk=4.0 * cutoff, f_root=0.75, f_parent=0.25)
        return TaskSpec(work_pre=0.05 * m, work_post=0.05 * m,
                        f_root=0.75, f_parent=0.25,
                        children=kids, post_children=post)
    return Workload("sort", rec(n), mem_intensity=0.8)


def strassen(depth: int = 5, base_work: float = 512.0) -> Workload:
    """Strassen: 7 recursive multiplies, then a parallel add/sub wave.

    ~7 GB of matrices; adds/subs at every level stream big temporaries →
    high parent-locality payoff, which is why the paper sees the largest
    scheduler win here (+17% DFWSRPT).
    """
    def rec(d: int) -> TaskSpec:
        # matrices at depth d have (1/2^d)^2 the area; work ~ area^1.5.
        area = 4.0 ** (depth - d)
        if d == 0:
            return TaskSpec(work_pre=base_work, f_root=0.45, f_parent=0.55)
        kids = [rec(d - 1) for _ in range(7)]
        post = _wave(2.0 * area, chunk=32.0, f_root=0.4, f_parent=0.6)
        return TaskSpec(work_pre=0.3 * area, work_post=0.05 * area,
                        f_root=0.4, f_parent=0.6,
                        children=kids, post_children=post)
    return Workload("strassen", rec(depth), mem_intensity=0.85)


def nqueens(n: int = 11, cutoff_depth: int = 4, seed: int = 0) -> Workload:
    """NQueens: irregular tree, tiny per-task state (the board copy) —
    compute bound, so NUMA effects are small (paper: +1.35% at best) and
    breadth-first's perfect balancing wins."""
    rng = np.random.RandomState(seed)

    def rec(depth: int, branch: int) -> TaskSpec:
        if depth >= cutoff_depth:
            # leaf explores the remaining subtree serially
            w = float(rng.randint(40, 120)) * (n - depth)
            return TaskSpec(work_pre=w, f_root=0.05, f_parent=0.1)
        # some placements are pruned — irregular fan-out
        k = max(1, branch - int(rng.randint(0, max(branch // 2, 1))))
        kids = [rec(depth + 1, branch - 1) for _ in range(k)]
        return TaskSpec(work_pre=2.0, work_post=0.5,
                        f_root=0.05, f_parent=0.1, children=kids)
    return Workload("nqueens", rec(0, n), mem_intensity=0.15)


def floorplan(branch: int = 6, depth: int = 5, seed: int = 1) -> Workload:
    """Floorplan: branch-and-bound over cell placements; small shared
    grid, moderate locality."""
    rng = np.random.RandomState(seed)

    def rec(d: int) -> TaskSpec:
        if d >= depth:
            return TaskSpec(work_pre=float(rng.randint(20, 80)),
                            f_root=0.2, f_parent=0.2)
        k = max(1, branch - int(rng.randint(0, branch // 2 + 1)))
        kids = [rec(d + 1) for _ in range(k)]
        return TaskSpec(work_pre=3.0, work_post=1.0,
                        f_root=0.2, f_parent=0.2, children=kids)
    return Workload("floorplan", rec(0), mem_intensity=0.3)


def sparselu(n: int = 20) -> Workload:
    """SparseLU (omp-for flavour): sequential outer k-loop, each step
    spawning a wide wave of block-update tasks over the master-allocated
    blocked matrix. The k-chain is modeled with nested post-waves."""
    def step(k: int) -> TaskSpec:
        wave = [TaskSpec(work_pre=30.0, f_root=0.6, f_parent=0.2)
                for _ in range(max(1, k * k // 4))]
        nxt = [step(k - 1)] if k > 1 else []
        # diagonal factorization (serial) → update wave → next k step
        return TaskSpec(work_pre=10.0, work_post=2.0, f_root=0.6,
                        f_parent=0.1, children=wave, post_children=nxt)
    return Workload("sparselu", step(n - 1), mem_intensity=0.7)


# ----------------------------------------------------------------------
# Flat (iterative, tree-free) CSR builders for paper-scale task counts.
#
# The divide-and-conquer benchmarks above are *uniform*: every node at a
# given recursion level has identical work/profile/fan-out, so the whole
# CSR table can be laid out level-by-level with numpy tiling — no
# TaskSpec objects, no recursion, millions of tasks in ~a second. For
# identical parameters these produce tables exactly equal to
# ``compile_tree`` of the recursive builders (covered by tests).
# ----------------------------------------------------------------------


def _uniform_flat(levels: list[dict], leaf: dict,
                  mem_intensity: float, name: str) -> Workload:
    """Build a Workload table for a uniform recursive tree.

    ``levels[k]`` describes the internal nodes at depth k:
      wp, wpo, fr, fp   — the node's own scalars,
      nch               — number of recursive children,
      nw, wave_w, wave_fr, wave_fp — its post-taskwait combine wave.
    ``leaf`` (wp, fr, fp) describes the nodes below the last level.

    Ids are assigned in BFS block order (matching ``compile_tree``):
    after the root, each node's block is [children..., wave...], blocks
    in parent-id order. A node's expansion block depends only on its
    level, so every per-level segment is one numpy tile.
    """
    if not levels:
        return Workload(name, None, mem_intensity, table=table_from_arrays(
            np.array([leaf["wp"]]), np.zeros(1),
            np.array([leaf["fr"]]), np.array([leaf["fp"]]),
            np.zeros(1, np.int64), np.zeros(1, np.int64)))
    lv0 = levels[0]
    seg_wp = [np.array([lv0["wp"]])]
    seg_wpo = [np.array([lv0["wpo"]])]
    seg_fr = [np.array([lv0["fr"]])]
    seg_fp = [np.array([lv0["fp"]])]
    seg_nc = [np.array([lv0["nch"]], np.int64)]
    seg_npw = [np.array([lv0["nw"]], np.int64)]
    count = 1
    for k, lv in enumerate(levels):
        child = levels[k + 1] if k + 1 < len(levels) else None
        nch, nw = lv["nch"], lv["nw"]
        if child is not None:
            c_wp, c_wpo = child["wp"], child["wpo"]
            c_fr, c_fp = child["fr"], child["fp"]
            c_nc, c_npw = child["nch"], child["nw"]
        else:
            c_wp, c_wpo = leaf["wp"], 0.0
            c_fr, c_fp = leaf["fr"], leaf["fp"]
            c_nc = c_npw = 0
        pat = lambda c_val, w_val, dt=np.float64: np.tile(
            np.array([c_val] * nch + [w_val] * nw, dtype=dt), count)
        seg_wp.append(pat(c_wp, lv["wave_w"]))
        seg_wpo.append(pat(c_wpo, 0.0))
        seg_fr.append(pat(c_fr, lv["wave_fr"]))
        seg_fp.append(pat(c_fp, lv["wave_fp"]))
        seg_nc.append(pat(c_nc, 0, np.int64))
        seg_npw.append(pat(c_npw, 0, np.int64))
        count *= nch
    tbl = table_from_arrays(
        np.concatenate(seg_wp), np.concatenate(seg_wpo),
        np.concatenate(seg_fr), np.concatenate(seg_fp),
        np.concatenate(seg_nc), np.concatenate(seg_npw))
    return Workload(name, None, mem_intensity, table=tbl)


def _wave_count(total_work: float, chunk: float) -> int:
    return max(1, int(round(total_work / chunk)))


def fft_flat(n: int = 1 << 21, cutoff: int = 1 << 3) -> Workload:
    """Flat-table twin of :func:`fft` (same structure, no TaskSpec tree)."""
    levels = []
    m = n
    while m > cutoff:
        total = 1.0 * m
        nw = _wave_count(total, 4.0 * cutoff)
        levels.append(dict(wp=0.1 * m, wpo=0.05 * m, fr=0.8, fp=0.2,
                           nch=2, nw=nw, wave_w=total / nw,
                           wave_fr=0.8, wave_fp=0.2))
        m //= 2
    leaf = dict(wp=m * np.log2(max(m, 2)), fr=0.75, fp=0.25)
    return _uniform_flat(levels, leaf, mem_intensity=0.9, name="fft")


def sort_flat(n: int = 1 << 22, cutoff: int = 1 << 4) -> Workload:
    """Flat-table twin of :func:`sort`."""
    levels = []
    m = n
    while m > cutoff:
        total = 1.2 * m
        nw = _wave_count(total, 4.0 * cutoff)
        levels.append(dict(wp=0.05 * m, wpo=0.05 * m, fr=0.75, fp=0.25,
                           nch=4, nw=nw, wave_w=total / nw,
                           wave_fr=0.75, wave_fp=0.25))
        m //= 4
    leaf = dict(wp=m * np.log2(max(m, 2)), fr=0.7, fp=0.3)
    return _uniform_flat(levels, leaf, mem_intensity=0.8, name="sort")


def strassen_flat(depth: int = 6, base_work: float = 512.0) -> Workload:
    """Flat-table twin of :func:`strassen`."""
    levels = []
    for d in range(depth, 0, -1):  # root has d == depth
        area = 4.0 ** (depth - d)
        total = 2.0 * area
        nw = _wave_count(total, 32.0)
        levels.append(dict(wp=0.3 * area, wpo=0.05 * area, fr=0.4, fp=0.6,
                           nch=7, nw=nw, wave_w=total / nw,
                           wave_fr=0.4, wave_fp=0.6))
    leaf = dict(wp=base_work, fr=0.45, fp=0.55)
    return _uniform_flat(levels, leaf, mem_intensity=0.85, name="strassen")


# ----------------------------------------------------------------------
# Irregular paper tier: level-synchronous builder with per-node random
# fan-out. Unlike the uniform builders above there is no per-level tile
# to repeat — instead each BFS level's child counts are drawn as one
# vectorized randint and the CSR arrays grow level by level, so a
# multi-million-task irregular tree still never materializes a TaskSpec.
# ----------------------------------------------------------------------


def nqueens_flat(n: int = 16, cutoff_depth: int = 6,
                 seed: int = 0) -> Workload:
    """Paper-scale twin of :func:`nqueens` (irregular fan-out, no tree).

    Same tasking structure and memory profile as the recursive builder —
    internal nodes spawn ``max(1, branch - randint(0, branch//2))``
    children with ``branch = n - depth``, leaves explore the remaining
    subtree serially — but the fan-outs of a whole level are drawn in
    one vectorized call and appended straight to the CSR arrays
    (level-synchronous BFS id order, which is exactly the layout
    ``table_from_arrays`` expects). Defaults give ~1.7M tasks, the
    BOTS-medium regime. Deterministic per seed; the rng *stream* differs
    from the recursive builder's depth-first draw order, so this is its
    own tier, not a bit-twin.
    """
    if cutoff_depth < 1:
        raise ValueError("cutoff_depth must be >= 1")
    rng = np.random.RandomState(seed)
    seg_wp, seg_nc = [], []
    m = 1  # nodes at the current level (root)
    for depth in range(cutoff_depth):
        branch = n - depth
        if branch < 1:
            raise ValueError(f"cutoff_depth {cutoff_depth} too deep for "
                             f"n={n} (branch hits zero)")
        draws = rng.randint(0, max(branch // 2, 1), size=m)
        k = np.maximum(1, branch - draws).astype(np.int64)
        seg_wp.append(np.full(m, 2.0))
        seg_nc.append(k)
        m = int(k.sum())
    # leaves explore their remaining placements serially
    leaf_w = rng.randint(40, 120, size=m).astype(np.float64) \
        * float(n - cutoff_depth)
    seg_wp.append(leaf_w)
    seg_nc.append(np.zeros(m, np.int64))
    wp = np.concatenate(seg_wp)
    nc = np.concatenate(seg_nc)
    total = wp.shape[0]
    n_internal = total - m
    wpo = np.zeros(total)
    wpo[:n_internal] = 0.5
    tbl = table_from_arrays(
        wp, wpo, np.full(total, 0.05), np.full(total, 0.1),
        nc, np.zeros(total, np.int64))
    return Workload("nqueens", None, mem_intensity=0.15, table=tbl)


def sparselu_flat(n: int = 240) -> Workload:
    """Paper-scale twin of :func:`sparselu` (flat CSR, no tree).

    SparseLU is the one BOTS benchmark whose parallelism is a
    *sequential outer chain*: step k factorizes a diagonal block, spawns
    a wave of ~k²/4 block updates, and only then (post-taskwait) steps
    to k-1. The chain folds directly into the level-by-level CSR
    layout: BFS id order is [step, its wave..., next step, its wave...]
    because wave tasks are leaves — so the whole table is two
    ``np.repeat`` patterns over the chain, never a TaskSpec. For equal
    ``n`` this is an exact twin of ``compile_tree(sparselu(n).root)``
    (covered by tests); the default ``n=240`` gives ~1.14M tasks, the
    BOTS-large regime.
    """
    if n < 2:
        raise ValueError("sparselu needs n >= 2")
    ks = np.arange(n - 1, 0, -1, dtype=np.int64)
    wcounts = np.maximum(1, ks * ks // 4)
    # interleaved [chain node, its wave] segments, one pair per k
    counts = np.empty(2 * ks.size, np.int64)
    counts[0::2] = 1
    counts[1::2] = wcounts

    def pat(chain_vals, wave_val, dt=np.float64):
        vals = np.empty(2 * ks.size, dt)
        vals[0::2] = chain_vals
        vals[1::2] = wave_val
        return np.repeat(vals, counts)

    npw_chain = np.ones(ks.size, np.int64)
    npw_chain[-1] = 0           # k == 1 ends the chain
    tbl = table_from_arrays(
        pat(10.0, 30.0), pat(2.0, 0.0), pat(0.6, 0.6), pat(0.1, 0.2),
        pat(wcounts, 0, np.int64), pat(npw_chain, 0, np.int64))
    return Workload("sparselu", None, mem_intensity=0.7, table=tbl)


WORKLOADS = {
    "fft": fft, "sort": sort, "strassen": strassen,
    "nqueens": nqueens, "floorplan": floorplan, "sparselu": sparselu,
}

PAPER_BUILDERS = {
    "fft": fft_flat, "sort": sort_flat, "strassen": strassen_flat,
    "nqueens": nqueens_flat, "sparselu": sparselu_flat,
}


def workload_cache_key(name: str, scale: str) -> str:
    """Content-addressed key of one ``make(name, scale)`` product.

    Builder identity = the instance coordinates plus a hash of the
    builder sources (this module *and* the table layout it compiles
    into): editing either changes the key, so stale cached tables miss
    instead of shadowing new code.
    """
    from . import bots as _self, compile_cache, table
    return compile_cache.digest_key(
        "workload", name, scale,
        compile_cache.source_fingerprint(_self, table))


def make(name: str, scale: str = "medium") -> Workload:
    """Scaled instances. 'medium'/'large' mirror the paper's input sets;
    'paper' builds flat tables at BOTS-like task counts (≥1M tasks) for
    the data-intensive benchmarks.

    Compiled tables persist in the :mod:`~.compile_cache`: a warm
    machine re-opens a paper-scale table as a read-only memory map in
    milliseconds instead of re-running the builder for 0.2–1.6 s. A
    cache hit returns a table-only workload (``root is None``) — the
    engines and every `make` call site consume only the table.
    """
    from .compile_cache import get_cache
    cache = get_cache()
    key = workload_cache_key(name, scale) if cache is not None else None
    if cache is not None:
        wl = cache.get_workload(key)
        if wl is not None:
            return wl
    wl = _build(name, scale)
    if cache is not None:
        cache.put_workload(key, wl)
    return wl


def _build(name: str, scale: str) -> Workload:
    if scale == "paper":
        builder = PAPER_BUILDERS.get(name)
        if builder is None:
            raise ValueError(
                f"no paper-scale tier for {name!r}; available: "
                f"{sorted(PAPER_BUILDERS)}")
        return builder()
    if name == "fft":
        return fft(n=(1 << 15) if scale == "medium" else (1 << 16))
    if name == "sort":
        return sort(n=(1 << 15) if scale == "medium" else (1 << 16))
    if name == "strassen":
        return strassen(depth=5 if scale == "medium" else 6)
    if name == "nqueens":
        return nqueens(n=11 if scale == "medium" else 12)
    if name == "floorplan":
        return floorplan(depth=5 if scale == "medium" else 6)
    if name == "sparselu":
        return sparselu(n=20 if scale == "medium" else 28)
    raise KeyError(name)
