"""BOTS-like task-DAG generators (paper §V benchmarks).

Each generator reproduces the *tasking structure* of the corresponding
Barcelona OpenMP Task Suite benchmark — recursion shape, fan-out, parallel
combine waves after taskwaits, and memory profile — at a
simulation-friendly scale. Work units are arbitrary (the simulator reports
speedups, which is what the paper reports too).

Memory profiles (``mem_intensity``, ``f_root``, ``f_parent``) follow the
paper's characterization: FFT / Strassen / Sort are "data intensive"
(multi-GB arrays allocated by the master → large first-touch/root traffic)
while NQueens / Floorplan are compute-dominated.
"""

from __future__ import annotations

import numpy as np

from .runtime import TaskSpec, Workload

__all__ = ["fft", "sort", "strassen", "nqueens", "floorplan", "sparselu",
           "WORKLOADS", "make"]


def _wave(total_work: float, chunk: float, f_root: float,
          f_parent: float) -> list[TaskSpec]:
    """A parallel combine wave: ~total_work split into chunk-sized tasks."""
    n = max(1, int(round(total_work / chunk)))
    w = total_work / n
    return [TaskSpec(work_pre=w, f_root=f_root, f_parent=f_parent)
            for _ in range(n)]


def fft(n: int = 1 << 15, cutoff: int = 1 << 4) -> Workload:
    """Cooley-Tukey recursion: two half-size sub-FFTs, then a parallel
    butterfly/twiddle wave (BOTS parallelizes the combine too).

    BOTS FFT (medium) spawns ~10M tasks over ~6 GB of master-allocated
    arrays; the butterfly wave streams the full root array → high f_root.
    Scaled here to ~n/cutoff leaf tasks.
    """
    def rec(m: int) -> TaskSpec:
        if m <= cutoff:
            return TaskSpec(work_pre=m * np.log2(max(m, 2)),
                            f_root=0.75, f_parent=0.25)
        kids = [rec(m // 2), rec(m // 2)]
        post = _wave(1.0 * m, chunk=4.0 * cutoff, f_root=0.8, f_parent=0.2)
        return TaskSpec(work_pre=0.1 * m, work_post=0.05 * m,
                        f_root=0.8, f_parent=0.2,
                        children=kids, post_children=post)
    return Workload("fft", rec(n), mem_intensity=0.9)


def sort(n: int = 1 << 15, cutoff: int = 1 << 4) -> Workload:
    """BOTS sort (cilksort): 4-way split, parallel merge wave after the
    taskwait. 8.5 GB root array (large input) ⇒ data intensive."""
    def rec(m: int) -> TaskSpec:
        if m <= cutoff:
            return TaskSpec(work_pre=m * np.log2(max(m, 2)),
                            f_root=0.7, f_parent=0.3)
        kids = [rec(m // 4) for _ in range(4)]
        post = _wave(1.2 * m, chunk=4.0 * cutoff, f_root=0.75, f_parent=0.25)
        return TaskSpec(work_pre=0.05 * m, work_post=0.05 * m,
                        f_root=0.75, f_parent=0.25,
                        children=kids, post_children=post)
    return Workload("sort", rec(n), mem_intensity=0.8)


def strassen(depth: int = 5, base_work: float = 512.0) -> Workload:
    """Strassen: 7 recursive multiplies, then a parallel add/sub wave.

    ~7 GB of matrices; adds/subs at every level stream big temporaries →
    high parent-locality payoff, which is why the paper sees the largest
    scheduler win here (+17% DFWSRPT).
    """
    def rec(d: int) -> TaskSpec:
        # matrices at depth d have (1/2^d)^2 the area; work ~ area^1.5.
        area = 4.0 ** (depth - d)
        if d == 0:
            return TaskSpec(work_pre=base_work, f_root=0.45, f_parent=0.55)
        kids = [rec(d - 1) for _ in range(7)]
        post = _wave(2.0 * area, chunk=32.0, f_root=0.4, f_parent=0.6)
        return TaskSpec(work_pre=0.3 * area, work_post=0.05 * area,
                        f_root=0.4, f_parent=0.6,
                        children=kids, post_children=post)
    return Workload("strassen", rec(depth), mem_intensity=0.85)


def nqueens(n: int = 11, cutoff_depth: int = 4, seed: int = 0) -> Workload:
    """NQueens: irregular tree, tiny per-task state (the board copy) —
    compute bound, so NUMA effects are small (paper: +1.35% at best) and
    breadth-first's perfect balancing wins."""
    rng = np.random.RandomState(seed)

    def rec(depth: int, branch: int) -> TaskSpec:
        if depth >= cutoff_depth:
            # leaf explores the remaining subtree serially
            w = float(rng.randint(40, 120)) * (n - depth)
            return TaskSpec(work_pre=w, f_root=0.05, f_parent=0.1)
        # some placements are pruned — irregular fan-out
        k = max(1, branch - int(rng.randint(0, max(branch // 2, 1))))
        kids = [rec(depth + 1, branch - 1) for _ in range(k)]
        return TaskSpec(work_pre=2.0, work_post=0.5,
                        f_root=0.05, f_parent=0.1, children=kids)
    return Workload("nqueens", rec(0, n), mem_intensity=0.15)


def floorplan(branch: int = 6, depth: int = 5, seed: int = 1) -> Workload:
    """Floorplan: branch-and-bound over cell placements; small shared
    grid, moderate locality."""
    rng = np.random.RandomState(seed)

    def rec(d: int) -> TaskSpec:
        if d >= depth:
            return TaskSpec(work_pre=float(rng.randint(20, 80)),
                            f_root=0.2, f_parent=0.2)
        k = max(1, branch - int(rng.randint(0, branch // 2 + 1)))
        kids = [rec(d + 1) for _ in range(k)]
        return TaskSpec(work_pre=3.0, work_post=1.0,
                        f_root=0.2, f_parent=0.2, children=kids)
    return Workload("floorplan", rec(0), mem_intensity=0.3)


def sparselu(n: int = 20) -> Workload:
    """SparseLU (omp-for flavour): sequential outer k-loop, each step
    spawning a wide wave of block-update tasks over the master-allocated
    blocked matrix. The k-chain is modeled with nested post-waves."""
    def step(k: int) -> TaskSpec:
        wave = [TaskSpec(work_pre=30.0, f_root=0.6, f_parent=0.2)
                for _ in range(max(1, k * k // 4))]
        nxt = [step(k - 1)] if k > 1 else []
        # diagonal factorization (serial) → update wave → next k step
        return TaskSpec(work_pre=10.0, work_post=2.0, f_root=0.6,
                        f_parent=0.1, children=wave, post_children=nxt)
    return Workload("sparselu", step(n - 1), mem_intensity=0.7)


WORKLOADS = {
    "fft": fft, "sort": sort, "strassen": strassen,
    "nqueens": nqueens, "floorplan": floorplan, "sparselu": sparselu,
}


def make(name: str, scale: str = "medium") -> Workload:
    """Scaled instances. 'medium'/'large' mirror the paper's input sets."""
    if name == "fft":
        return fft(n=(1 << 15) if scale == "medium" else (1 << 16))
    if name == "sort":
        return sort(n=(1 << 15) if scale == "medium" else (1 << 16))
    if name == "strassen":
        return strassen(depth=5 if scale == "medium" else 6)
    if name == "nqueens":
        return nqueens(n=11 if scale == "medium" else 12)
    if name == "floorplan":
        return floorplan(depth=5 if scale == "medium" else 6)
    if name == "sparselu":
        return sparselu(n=20 if scale == "medium" else 28)
    raise KeyError(name)
