"""Pure-Python flat-array simulation engine.

This is the portable reference implementation of the flat engine: it
preserves the original (seed) engine's behavior draw-for-draw — same
event ordering, same ``numpy.random.RandomState`` consumption, same
float-operation association — while replacing per-task ``_Run`` object
allocation with integer indices into the compiled :class:`TaskTable`
arrays, and per-call recomputation with precomputed lookup tables:

  * per-class × node NUMA penalty rows
    ``mu_lambda * (f_root * d_root[n] + f_parent * d(n, parent_node))``
    built lazily (only (class, exec-node) pairs that actually occur);
  * per-core queue-op and steal-probe costs;
  * ``collections.deque`` task pools (the seed engine's ``pop(0)``
    steal was O(queue length)).

Scheduler identity never reaches this loop: the context carries the
spec's ``queue_shared`` / ``child_first`` flags and a compiled
:class:`~.policy.VictimPlan`, whose pre-lowered group list is
interpreted per steal sweep (a fully static plan skips even that).

Fault injection works the same way: the context may carry a compiled
:class:`~.faults.FaultPlan` (per-core speed multipliers + per-thread
merged offline windows in flat CSR arrays). The loop consults it at two
points — when a thread's event fires (is the thread offline now?) and
when an execution cost is known (does an offline window interrupt it?).
A thread entering a finite window re-queues its in-hand task (stealable
by others), makes its queued tasks reclaimable (one thief wake per
task), and resumes with a fresh acquire at the window end; a window
ending at ``+inf`` is a permanent failure — the thread's work is
reclaimed the same way and it never reschedules, passing any wake it
consumed on so queued work cannot strand. All fault randomness was
drawn at plan-compile time from a dedicated stream, so the engine's own
``RandomState(seed)`` draw order — and therefore every fault-free
result — is untouched bit for bit.

A step-count watchdog (``ctx["max_steps"]``) converts a hung event
loop into a diagnosable ``status=1`` return instead of an infinite
loop; ``status=2`` reports a drained loop that completed fewer tasks
than the table holds (stranded work).

The C kernel (:mod:`._csim`) is a transcription of this loop; the
golden-parity suite pins both to fixtures recorded from the seed
engine.
"""

from __future__ import annotations

import heapq
import pickle
import traceback
import warnings
from collections import deque

__all__ = ["run", "run_batch", "run_supervised"]


def run(ctx) -> dict:
    heappush = heapq.heappush
    heappop = heapq.heappop
    tbl = ctx["table"]
    (wp_l, wpo_l, fc_l, nc_l, fpw_l, npw_l, par_l, cls_l) = tbl.lists()
    n_tasks = tbl.n
    T = ctx["T"]
    cores = ctx["cores"]          # mutated in place under migration
    rng = ctx["rng"]
    core_node_l = ctx["core_node_arr"].tolist()
    NN = ctx["num_nodes"]
    nd_l = [ctx["node_dist_flat"][n * NN:(n + 1) * NN].tolist()
            for n in range(NN)]
    root_dist_l = ctx["root_dist"].tolist()
    rnode0 = ctx["root_node0"]
    num_cores_m = ctx["num_cores"]
    rdn = ctx["runtime_data_node"]
    migration_rate = ctx["migration_rate"]
    hop_lambda_steal = ctx["hop_lambda_steal"]
    lock_time = ctx["lock_time"]
    deque_lock_time = ctx["deque_lock_time"]
    steal_time = ctx["steal_time"]
    spawn_time = ctx["spawn_time"]
    wake_latency = ctx["wake_latency"]
    qop_time = ctx["qop_time"]
    cache_refill = ctx["cache_refill"]
    mu_lam = ctx["mem_intensity"] * ctx["hop_lambda"]
    depth_first = not ctx["queue_shared"]
    wf_like = ctx["child_first"]
    vplan = ctx["vplan"]
    plan_groups = vplan.py_groups
    static_orders = vplan.static_order
    shuffle = rng.shuffle
    INF = float("inf")
    max_steps = ctx.get("max_steps") or (1 << 62)
    fplan = ctx.get("fault_plan")
    have_faults = fplan is not None
    if have_faults:
        fspeed = fplan.speed.tolist()
        fwstart = fplan.win_start.tolist()
        fwend = fplan.win_end.tolist()
        fwoff = fplan.win_off.tolist()
        wcur = fwoff[:T]          # per-thread window cursor (monotone)
        wlim = fwoff[1:T + 1]

    # --- precomputed cost tables (exact seed expressions) ---
    cls_fr = tbl.cls_f_root.tolist()
    cls_fp = tbl.cls_f_parent.tolist()
    PEN: list[list] = [[None] * NN for _ in range(tbl.num_classes)]

    def pen_row(c: int, n: int) -> list[float]:
        fr = cls_fr[c]
        fp = cls_fp[c]
        dr = root_dist_l[n]
        nd_n = nd_l[n]
        row = [mu_lam * (fr * dr + fp * nd_n[pn]) for pn in range(NN)]
        PEN[c][n] = row
        return row

    if rdn is None:
        qop_c = [qop_time] * num_cores_m
    else:
        qop_c = [qop_time * (1.0 + hop_lambda_steal
                             * nd_l[core_node_l[c]][rdn])
                 for c in range(num_cores_m)]
    # steal-probe cost per (thief core, victim core); rows built lazily
    if rdn is None:
        probe_rows: list = [None] * num_cores_m

        def probe_row(ct: int) -> list[float]:
            tn = core_node_l[ct]
            row = [steal_time * (1.0 + hop_lambda_steal
                                 * float(nd_l[tn][core_node_l[cv]]))
                   for cv in range(num_cores_m)]
            probe_rows[ct] = row
            return row
    else:
        probe_const = [steal_time * (1.0 + hop_lambda_steal
                                     * float(nd_l[core_node_l[ct]][rdn]))
                       for ct in range(num_cores_m)]

    # --- mutable simulation state (flat arrays, no objects) ---
    local = [deque() for _ in range(T)]
    shared: deque = deque()
    sl_free = 0.0
    sl_waited = 0.0
    dl_free = [0.0] * T
    parked: set[int] = set()
    events: list = []
    seq = 0
    steals = 0
    failed = 0
    remote = 0.0
    total_exec = 0.0
    live = 1
    makespan = 0.0
    pending = [0] * n_tasks
    exec_node = [0] * n_tasks
    phase = bytearray(n_tasks)
    reclaimed = 0
    reexec = 0
    fault_lost = 0.0
    executed = 0
    steps = 0
    status = 0
    last_t = 0.0
    # always-on locality aggregates (O(1) per event; see SimResult)
    steal_hops = [0] * (ctx.get("max_hop", 0) + 1)
    node_tasks = [0] * NN
    node_remote = [0.0] * NN
    # event tracing: extend flat row-major lists in the hot loop,
    # columnize once at the end (TraceBuffer.from_flat) — an order of
    # magnitude cheaper per event than indexed array stores
    tracing = bool(ctx.get("trace"))
    ex_ev: list = []
    st_ev: list = []
    mg_ev: list = []
    ex_append, st_append, mg_append = \
        ex_ev.extend, st_ev.extend, mg_ev.extend

    def go_offline(now, th, task, cidx):
        # Thread `th` hits offline window `cidx` at `now`, carrying
        # `task` if >= 0. The in-hand task is re-queued (stealable);
        # queued tasks stay in place but one thief is woken per task so
        # they are reclaimed by stealing. A finite window resumes the
        # thread with a fresh acquire at the window end; end == inf is a
        # permanent failure — no resume, and an empty-handed dead thread
        # passes a consumed wake on so live work cannot strand.
        nonlocal seq, reclaimed
        nq = len(local[th]) if depth_first else 0
        if task >= 0:
            nq += 1
            if depth_first:
                local[th].append(task)
            else:
                shared.append(task)
        reclaimed += nq
        while nq > 0 and parked:
            seq += 1
            heappush(events, (now + wake_latency, seq, parked.pop(), -1))
            nq -= 1
        if fwend[cidx] != INF:
            seq += 1
            heappush(events, (fwend[cidx], seq, th, -1))
        elif task < 0 and parked:
            seq += 1
            heappush(events, (now, seq, parked.pop(), -1))

    # ignition: master (thread 0) runs the root; workers go hunting
    seq += 1
    heappush(events, (0.0, seq, 0, 0))
    for th in range(1, T):
        seq += 1
        heappush(events, (0.0, seq, th, -1))

    while events:
        t, _, th, task = heappop(events)
        steps += 1
        if steps > max_steps:
            status = 1
            last_t = t
            break
        if have_faults:
            c = wcur[th]
            lim = wlim[th]
            while c < lim and fwend[c] <= t:
                c += 1
            wcur[th] = c
            if c < lim and fwstart[c] <= t:
                go_offline(t, th, task, c)
                continue
        if task < 0:
            # ---- acquire: local pop / steal sweep / shared FIFO ----
            if depth_first:
                lp = local[th]
                if lp:
                    task = lp.pop()
                    t += qop_c[cores[th]]
                else:
                    order = static_orders[th]
                    if order is None:
                        # interpret the compiled sweep: one shuffle per
                        # group with >1 unit, draws matching the seed.
                        order = []
                        for tag, payload in plan_groups[th]:
                            if tag == 0:          # static run
                                order.extend(payload)
                            elif tag == 1:        # singleton units
                                g = list(payload)
                                shuffle(g)
                                order.extend(g)
                            else:                 # multi-victim units
                                units = list(payload)
                                shuffle(units)
                                for u in units:
                                    order.extend(u)
                    ct = cores[th]
                    if rdn is None:
                        prow = probe_rows[ct]
                        if prow is None:
                            prow = probe_row(ct)
                        pc = None
                    else:
                        pc = probe_const[ct]
                    task = -1
                    for v in order:
                        t += prow[cores[v]] if pc is None else pc
                        lv = local[v]
                        if lv:
                            f = dl_free[v]
                            t = (f if f > t else t) + deque_lock_time
                            dl_free[v] = t
                            steals += 1
                            task = lv.popleft()  # steal from the back
                            # hop distance thief-core → victim-core (the
                            # stolen task's data locality, independent of
                            # the probe cost, which models queue metadata)
                            d = nd_l[core_node_l[ct]][core_node_l[cores[v]]]
                            steal_hops[d] += 1
                            if tracing:
                                st_append((t, th, v, task, d))
                            break
                        failed += 1
                    if task < 0:
                        if live > 0:
                            parked.add(th)
                        continue
            else:
                # breadth-first: peek cheaply, then serialize on the lock
                if not shared:
                    if live > 0:
                        parked.add(th)
                    continue
                start = sl_free if sl_free > t else t
                sl_waited += start - t
                t = start + lock_time
                sl_free = t
                if not shared:
                    if live > 0:
                        parked.add(th)
                    continue
                task = shared.popleft()

        # ---- run `task` on thread th at time t ----
        if migration_rate > 0.0 and rng.random_sample() < migration_rate:
            oldc = cores[th]
            cores[th] = int(rng.randint(num_cores_m))
            t += cache_refill
            if tracing:
                mg_append((t, th, oldc, cores[th]))
        core = cores[th]
        n = core_node_l[core]
        exec_node[task] = n
        pr = par_l[task]
        pn = exec_node[pr] if pr >= 0 else rnode0
        row = PEN[cls_l[task]][n]
        if row is None:
            row = pen_row(cls_l[task], n)
        pen = row[pn]
        w = wp_l[task]
        cost = w * (1.0 + pen)
        if have_faults:
            cost = cost * fspeed[core]
            c = wcur[th]
            lim = wlim[th]
            # t advanced during acquire (probes, locks): windows may
            # have closed — or opened — since the top-of-loop check.
            while c < lim and fwend[c] <= t:
                c += 1
            wcur[th] = c
            if c < lim and fwstart[c] < t + cost:
                # preempted/killed mid-execution: partial work is lost
                # and the task re-executes (here after the window, or
                # wherever it is stolen to meanwhile).
                s = fwstart[c]
                if s < t:
                    s = t
                fault_lost += s - t
                reexec += 1
                go_offline(s, th, task, c)
                continue
        remote += w * pen
        total_exec += cost
        node_tasks[n] += 1
        node_remote[n] += w * pen
        if tracing:
            ex_append((task, th, core, n,
                       len(local[th]) if depth_first else len(shared),
                       t, t + cost))
        t += cost
        executed += 1

        nk = nc_l[task]
        if nk:
            base = fc_l[task]
            pending[task] = nk
            live += nk
            t += spawn_time * nk
            qc = qop_c[core]
            if wf_like:
                # work-first: dive into the first child, queue the rest
                lp = local[th]
                for k in range(base + nk - 1, base, -1):
                    t += qc
                    lp.append(k)
                    if parked:
                        seq += 1
                        heappush(events,
                                 (t + wake_latency, seq, parked.pop(), -1))
                seq += 1
                heappush(events, (t, seq, th, base))
                continue
            if depth_first:  # cilk: queue all, re-acquire own front
                lp = local[th]
                for k in range(base + nk - 1, base - 1, -1):
                    t += qc
                    lp.append(k)
                    if parked:
                        seq += 1
                        heappush(events,
                                 (t + wake_latency, seq, parked.pop(), -1))
            else:  # bf: shared FIFO in spawn order, one lock op each
                for k in range(base, base + nk):
                    start = sl_free if sl_free > t else t
                    sl_waited += start - t
                    t = start + lock_time
                    sl_free = t
                    shared.append(k)
                    if parked:
                        seq += 1
                        heappush(events,
                                 (t + wake_latency, seq, parked.pop(), -1))
            seq += 1
            heappush(events, (t, seq, th, -1))
            continue

        # ---- leaf: propagate completion up the tree ----
        live -= 1
        node = task
        while True:
            parent = par_l[node]
            if parent < 0:
                break
            pd = pending[parent] - 1
            pending[parent] = pd
            if pd > 0:
                break
            if phase[parent] == 0 and npw_l[parent]:
                # taskwait passed: spawn the combine wave here — this
                # thread just finished the last child, hottest caches.
                phase[parent] = 1
                k = npw_l[parent]
                fp0 = fpw_l[parent]
                pending[parent] = k
                live += k
                t += spawn_time * k
                if depth_first:
                    qc = qop_c[cores[th]]
                    lp = local[th]
                    for j in range(fp0 + k - 1, fp0 - 1, -1):
                        t += qc
                        lp.append(j)
                        if parked:
                            seq += 1
                            heappush(events, (t + wake_latency, seq,
                                              parked.pop(), -1))
                else:
                    for j in range(fp0 + k - 1, fp0 - 1, -1):
                        start = sl_free if sl_free > t else t
                        sl_waited += start - t
                        t = start + lock_time
                        sl_free = t
                        shared.append(j)
                        if parked:
                            seq += 1
                            heappush(events, (t + wake_latency, seq,
                                              parked.pop(), -1))
                break
            w2 = wpo_l[parent]
            if w2 > 0.0:
                # join continuation with the parent's locality profile
                pn2 = exec_node[parent]
                row2 = PEN[cls_l[parent]][n]
                if row2 is None:
                    row2 = pen_row(cls_l[parent], n)
                pen2 = row2[pn2]
                c2 = w2 * (1.0 + pen2)
                if have_faults:
                    c2 = c2 * fspeed[core]
                remote += w2 * pen2
                total_exec += c2
                node_remote[n] += w2 * pen2
                t += c2
            node = parent
        if t > makespan:
            makespan = t
        seq += 1
        heappush(events, (t, seq, th, -1))

    if status == 0 and executed != n_tasks:
        status = 2          # loop drained with work stranded
        last_t = makespan
    elif status == 0:
        last_t = makespan
    out = dict(makespan=makespan, remote=remote, total_exec=total_exec,
               queue_wait=sl_waited, steals=steals, failed=failed,
               reclaimed=reclaimed, reexec=reexec, fault_lost=fault_lost,
               executed=executed, steps=steps, status=status, last_t=last_t,
               steal_hops=steal_hops, node_tasks=node_tasks,
               node_remote=node_remote)
    if tracing:
        from .trace import TraceBuffer
        out["trace"] = TraceBuffer.from_flat(ex_ev, st_ev, mg_ev)
    return out


# ------------------------------------------------------------------ #
# batched execution: multiprocessing pool over cells                 #
# ------------------------------------------------------------------ #
#
# The prepared contexts (compiled TaskTables, victim plans, FaultPlans
# — all the heavy flat arrays) are built once in the parent and shared
# with workers by setting the module global below *before* forking the
# pool: fork-children inherit the whole list, so nothing but a cell
# index travels to a worker and nothing but a small result dict (or a
# picklable exception) travels back. A failed cell is returned as the
# exception object, not raised, so one bad cell cannot poison the
# batch; callers map these to CellError.

_MP_CTXS: list | None = None
_warned_no_pool = False


def _picklable(exc: BaseException) -> BaseException:
    """Exceptions must survive the trip back through the pool's result
    pickle; anything that doesn't round-trip is flattened to a
    RuntimeError carrying the original type and message. The worker's
    formatted stack rides along as ``remote_traceback`` (a plain string
    lives in ``__dict__``, which ``BaseException.__reduce__`` preserves
    through the pickle) so a fork-worker failure is debuggable from the
    parent."""
    try:
        pickle.loads(pickle.dumps(exc))
    except Exception:
        exc = RuntimeError(f"{type(exc).__name__}: {exc}")
    exc.remote_traceback = traceback.format_exc()
    return exc


def _mp_cell(i: int):
    try:
        return ("ok", run(_MP_CTXS[i]))
    except Exception as e:           # noqa: BLE001 — isolate the cell
        return ("err", _picklable(e))


def run_batch(ctxs, workers: int = 1) -> list:
    """Run many prepared contexts, optionally across a process pool.

    Returns one entry per context: the result dict, or the exception the
    cell raised (callers map these to ``CellError``). Results are keyed
    by cell index, so output order — and every result bit — is identical
    to the serial loop at any worker count. When the pool cannot start
    (no fork support, sandboxed env) the batch degrades to ``workers=1``
    with a one-time warning, mirroring the C→py engine fallback.
    """
    global _MP_CTXS, _warned_no_pool
    ctxs = list(ctxs)
    if workers > 1 and len(ctxs) > 1:
        try:
            import multiprocessing as mp
            mpctx = mp.get_context("fork")
            _MP_CTXS = ctxs     # set BEFORE fork: children inherit it
            try:
                with mpctx.Pool(min(workers, len(ctxs))) as pool:
                    tagged = pool.map(_mp_cell, range(len(ctxs)))
                return [out for _, out in tagged]
            finally:
                _MP_CTXS = None
        except (ImportError, ValueError, OSError) as e:
            if not _warned_no_pool:
                _warned_no_pool = True
                warnings.warn(
                    f"multiprocessing pool unavailable ({e}); "
                    "running batch with workers=1",
                    RuntimeWarning, stacklevel=2)
    out = []
    for ctx in ctxs:
        try:
            out.append(run(ctx))
        except Exception as e:       # noqa: BLE001 — isolate the cell
            out.append(e)
    return out


# ------------------------------------------------------------------ #
# supervised execution: kill-capable workers + wall-clock timeouts   #
# ------------------------------------------------------------------ #
#
# mp.Pool cannot enforce a per-task deadline — a wedged C call or a
# SIGKILLed worker hangs or poisons the whole map. The supervisor below
# manages raw fork Processes over Pipes, one in-flight cell per worker,
# so a cell that overruns its wall-clock budget (or whose worker dies)
# is killed + its worker respawned while sibling cells keep running.
# Contexts and the per-cell run function (either engine's ``run``)
# travel to workers by fork inheritance via the module globals, same
# as the plain pool above; respawns fork from the supervising parent,
# which still holds them.

_SUP_CTXS: list | None = None
_SUP_RUN = None


def _sup_child(conn):
    """Worker main: receive a cell index, run it, send a tagged reply.

    A ``None`` message (or a closed pipe) shuts the worker down. Errors
    are isolated per cell, flattened picklable with the remote stack
    attached — the worker survives to take the next assignment.
    """
    while True:
        try:
            i = conn.recv()
        except (EOFError, OSError):
            return
        if i is None:
            return
        try:
            reply = ("ok", i, _SUP_RUN(_SUP_CTXS[i]))
        except Exception as e:       # noqa: BLE001 — isolate the cell
            reply = ("err", i, _picklable(e))
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            return


def run_supervised(ctxs, workers: int, timeout: "float | None",
                   run_fn=None) -> list:
    """Run many prepared contexts under kill-capable supervision.

    Like :func:`run_batch`, but each worker is a directly-managed fork
    :class:`~multiprocessing.Process` with a dedicated pipe, one
    in-flight cell at a time. Returns one *tagged* entry per context:

    * ``("ok", result_dict)`` — the cell completed;
    * ``("err", exc)``       — the cell raised (picklable, with
      ``remote_traceback``);
    * ``("timeout", t)``     — the cell exceeded ``timeout`` seconds of
      wall clock; its worker was killed and respawned;
    * ``("died",)``          — the worker vanished mid-cell (SIGKILL,
      OOM-kill, segfault); it was respawned and the batch completed.

    ``run_fn`` is the per-cell engine entry (default: this module's
    :func:`run`; pass ``_csim.run`` to supervise the C kernel — the
    whole point of process-level supervision is that it works even when
    the hang is inside a C call the parent cannot interrupt). When fork
    is unavailable the batch degrades to in-process serial execution
    with a one-time warning — error isolation survives, timeouts and
    kill-resilience cannot.
    """
    global _SUP_CTXS, _SUP_RUN, _warned_no_pool
    ctxs = list(ctxs)
    n = len(ctxs)
    if not n:
        return []
    run_fn = run_fn or run
    try:
        import multiprocessing as mp
        from multiprocessing import connection as mpconn
        mpctx = mp.get_context("fork")
    except (ImportError, ValueError, OSError) as e:
        if not _warned_no_pool:
            _warned_no_pool = True
            warnings.warn(
                f"multiprocessing pool unavailable ({e}); running "
                "supervised batch in-process (timeouts not enforced)",
                RuntimeWarning, stacklevel=2)
        out = []
        for ctx in ctxs:
            try:
                out.append(("ok", run_fn(ctx)))
            except Exception as exc:  # noqa: BLE001 — isolate the cell
                out.append(("err", exc))
        return out

    import time
    results: list = [None] * n
    queue = list(range(n))           # cells awaiting a worker
    _SUP_CTXS, _SUP_RUN = ctxs, run_fn
    # worker slot: [proc, parent_conn, cell (-1 idle), deadline]
    slots: list = []

    def spawn():
        pconn, cconn = mpctx.Pipe()
        p = mpctx.Process(target=_sup_child, args=(cconn,), daemon=True)
        p.start()
        cconn.close()
        return [p, pconn, -1, float("inf")]

    def retire(slot):
        p, pc = slot[0], slot[1]
        try:
            pc.close()
        except OSError:
            pass
        p.kill()
        p.join()

    try:
        for _ in range(max(1, min(workers, n))):
            slots.append(spawn())
        done = 0
        while done < n:
            now = time.monotonic()
            for slot in slots:
                if slot[2] < 0 and queue:
                    i = queue.pop(0)
                    try:
                        slot[1].send(i)
                    except (BrokenPipeError, OSError):
                        # worker died between cells: replace it and
                        # put the cell back
                        queue.insert(0, i)
                        retire(slot)
                        slot[:] = spawn()
                        continue
                    slot[2] = i
                    slot[3] = now + timeout if timeout else float("inf")
            busy = [s for s in slots if s[2] >= 0]
            if not busy:
                continue        # every assignment hit a dead pipe: retry
            deadline = min(s[3] for s in busy)
            wait_for = None if deadline == float("inf") \
                else max(deadline - time.monotonic(), 0.0)
            ready = mpconn.wait([s[1] for s in busy], timeout=wait_for)
            ready_set = set(ready)
            now = time.monotonic()
            for slot in busy:
                if slot[1] in ready_set:
                    try:
                        tag, i, payload = slot[1].recv()
                    except (EOFError, OSError):
                        # worker vanished mid-cell (SIGKILL / segfault)
                        results[slot[2]] = ("died",)
                        done += 1
                        retire(slot)
                        slot[:] = spawn()
                        continue
                    results[i] = (tag, payload)
                    done += 1
                    slot[2], slot[3] = -1, float("inf")
                elif now >= slot[3]:
                    results[slot[2]] = ("timeout", timeout)
                    done += 1
                    retire(slot)
                    slot[:] = spawn()
    finally:
        _SUP_CTXS = _SUP_RUN = None
        for slot in slots:
            try:
                slot[1].send(None)
            except (BrokenPipeError, OSError):
                pass
        for slot in slots:
            slot[0].join(timeout=1.0)
            if slot[0].is_alive():
                slot[0].kill()
                slot[0].join()
            try:
                slot[1].close()
            except OSError:
                pass
    return results
