"""Build/load machinery for the C simulation kernel (_csim.c).

The kernel is compiled on first use with the system C compiler into the
persistent compile cache (see :mod:`~.compile_cache`), keyed by (source
hash, compiler version, flags) — only the first process on a machine
ever invokes the compiler; every later one dlopens the cached ``.so``
(the compiler-version probe itself is persisted, so a warm process
spawns nothing). With ``REPRO_SIM_CACHE=0`` artifacts go to a
per-process temp dir instead. When no compiler (or loading) is
available the caller falls back to the pure-Python engine — same
results, slower. Set ``REPRO_SIM_ENGINE`` to ``py`` / ``c`` / ``auto``
(default) to force a path.

Concurrent processes racing the build are safe: each compiles into a
private ``mkstemp`` file and atomically ``os.replace``\\ s it onto the
keyed artifact path (equal keys ⇒ equal content, last rename wins with
identical bytes); a builder whose compile *fails* while the artifact
exists reuses the winner's output.

IMPORTANT: ``-ffp-contract=off`` is required — FMA contraction would
change float results and break bit-parity with the Python engine.
"""

from __future__ import annotations

import ctypes as ct
import hashlib
import json
import os
import shutil
import subprocess
import tempfile
import warnings

import numpy as np

_SRC = os.path.join(os.path.dirname(__file__), "_csim.c")
# headers textually included into _csim.c; they never appear on the
# compile command line but must participate in the artifact hash, or a
# header-only change would keep serving a stale cached kernel.
_HDRS = (os.path.join(os.path.dirname(__file__), "_csim_core.h"),)
_CFLAGS = ["-O2", "-fPIC", "-shared", "-ffp-contract=off"]

_lib = None
_load_attempted = False
load_error: str | None = None

# True when *this* process ran the compiler (vs dlopening a cached
# artifact) — the cross-process cache smoke asserts a warm process
# keeps it False.
compiled_this_process = False

# True once loaded with the pthread worker pool compiled in; a toolchain
# without pthread support falls back to a -DCSIM_NO_THREADS build and
# run_batch degrades to workers=1 with a one-time warning.
threads_supported = False
_warned_no_threads = False

_tmp_dir: str | None = None      # per-process fallback when caching is off
_cc_memo: dict = {}              # cc path -> version string, per process


def reset() -> None:
    """Forget a previous load attempt (e.g. the toolchain changed)."""
    global _lib, _load_attempted, load_error
    global threads_supported, _warned_no_threads, compiled_this_process
    _lib = None
    _load_attempted = False
    load_error = None
    threads_supported = False
    _warned_no_threads = False
    compiled_this_process = False

_f64p = np.ctypeslib.ndpointer(np.float64, flags="C_CONTIGUOUS")
_i64p = np.ctypeslib.ndpointer(np.int64, flags="C_CONTIGUOUS")
_u32p = np.ctypeslib.ndpointer(np.uint32, flags="C_CONTIGUOUS")


def _csim_dir() -> str:
    """Artifact directory for compiled kernels.

    ``<cache root>/csim`` under the persistent compile cache; when
    caching is disabled (``REPRO_SIM_CACHE=0``) a per-process temp dir —
    disabled means no cross-process persistence at all.
    """
    from .compile_cache import cache_root
    root = cache_root()
    if root is not None:
        return os.path.join(root, "csim")
    global _tmp_dir
    if _tmp_dir is None:
        _tmp_dir = tempfile.mkdtemp(prefix="repro-sim-csim-")
    return _tmp_dir


def _resolve_cc() -> str | None:
    env = os.environ.get("CC")
    if env:
        return shutil.which(env) or env
    return shutil.which("cc") or shutil.which("gcc")


def _cc_version(cc: str, cache_dir: str) -> str:
    """Compiler identity (first ``--version`` line) for the artifact key.

    Memoized per process and persisted keyed by the compiler binary's
    (path, mtime, size) — a warm process reads the probe file instead of
    spawning the compiler, so a cache hit is subprocess-free. A swapped
    or upgraded compiler changes the probe key *and* re-probes, which
    rotates the ``.so`` tag.
    """
    ver = _cc_memo.get(cc)
    if ver is not None:
        return ver
    probe = None
    try:
        st = os.stat(cc)
        ident = hashlib.sha1(
            f"{cc}:{st.st_mtime_ns}:{st.st_size}".encode()).hexdigest()[:16]
        probe = os.path.join(cache_dir, f"ccprobe_{ident}.json")
        with open(probe, "r", encoding="utf-8") as f:
            ver = str(json.load(f)["version"])
        _cc_memo[cc] = ver
        return ver
    except (OSError, ValueError, KeyError, TypeError):
        pass
    try:
        r = subprocess.run([cc, "--version"], capture_output=True,
                           timeout=30)
        lines = (r.stdout or r.stderr).decode("utf-8",
                                              "replace").splitlines()
        ver = lines[0].strip() if lines else "unknown"
    except (OSError, subprocess.SubprocessError):
        ver = "unknown"
    _cc_memo[cc] = ver
    if probe is not None:
        try:
            os.makedirs(cache_dir, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".json")
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump({"cc": cc, "version": ver}, f)
            os.replace(tmp, probe)
        except OSError:
            pass
    return ver


def _build_one(flags: list[str], src: bytes, cc: "str | None",
               cc_ver: str, cache_dir: str) -> str:
    global compiled_this_process
    tag = hashlib.sha1(src + " ".join(flags).encode()
                       + cc_ver.encode()).hexdigest()[:16]
    out = os.path.join(cache_dir, f"csim_{tag}.so")
    if os.path.exists(out):
        return out
    if cc is None:
        raise RuntimeError("no C compiler found")
    os.makedirs(cache_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(suffix=".so", dir=cache_dir)
    os.close(fd)
    try:
        try:
            subprocess.run([cc, *flags, _SRC, "-o", tmp],
                           check=True, capture_output=True, timeout=120)
        except (subprocess.SubprocessError, OSError):
            # a concurrent builder may have published the artifact while
            # our compile was failing — the loser reuses the winner's
            if os.path.exists(out):
                return out
            raise
        os.replace(tmp, out)  # atomic: concurrent builders race safely
        compiled_this_process = True
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return out


def _build() -> tuple[str, bool]:
    """Compile (or reuse) the kernel; returns (path, threaded).

    Tries the pthread worker-pool build first; a toolchain that rejects
    ``-pthread`` gets a ``-DCSIM_NO_THREADS`` build (serial batch loop,
    identical results) instead.
    """
    src = b""
    for path in (_SRC, *_HDRS):
        with open(path, "rb") as f:
            src += f.read()
    cache_dir = _csim_dir()
    cc = _resolve_cc()
    cc_ver = _cc_version(cc, cache_dir) if cc is not None else "none"
    try:
        return _build_one(_CFLAGS + ["-pthread"], src, cc, cc_ver,
                          cache_dir), True
    except subprocess.CalledProcessError:
        return _build_one(_CFLAGS + ["-DCSIM_NO_THREADS"], src, cc,
                          cc_ver, cache_dir), False


_uptr = np.ctypeslib.ndpointer(np.uintp, flags="C_CONTIGUOUS")


def load():
    """Returns the loaded library or None (with load_error set)."""
    global _lib, _load_attempted, load_error, threads_supported
    if _load_attempted:
        return _lib
    _load_attempted = True
    try:
        path, threaded = _build()
        lib = ct.CDLL(path)
        lib.sim_run.restype = ct.c_int
        lib.sim_run.argtypes = [
            _f64p, _i64p,                     # dpar, ipar
            _f64p, _f64p, _f64p, _f64p,       # wp, wpo, fr, fp
            _i64p, _i64p, _i64p, _i64p, _i64p,  # fc, nc, fpw, npw, par
            _i64p, _i64p, _f64p,              # core_node, node_dist, root_dist
            _i64p,                            # cores (in/out)
            _i64p, _i64p, _i64p, _i64p,       # victim plan (goff/uoff/voff/v)
            _f64p, _i64p, _f64p, _f64p,       # fault plan (speed/off/start/end)
            _f64p, _i64p,                     # dout, iout
            _i64p, _i64p, _f64p,              # agg steal_hops/node_tasks/remote
            ct.c_void_p,                      # trace handle (NULL = untraced)
        ]
        lib.sim_run_batch.restype = ct.c_int64
        # n_cfg, n_workers, 27 arrays of per-config pointers, then flat
        # outputs + per-config return codes
        lib.sim_run_batch.argtypes = (
            [ct.c_int64, ct.c_int64] + [_uptr] * 27
            + [_f64p, _i64p, _i64p])
        lib.sim_trace_new.restype = ct.c_void_p
        lib.sim_trace_new.argtypes = [ct.c_int64]
        lib.sim_trace_free.restype = None
        lib.sim_trace_free.argtypes = [ct.c_void_p]
        lib.sim_trace_counts.restype = None
        lib.sim_trace_counts.argtypes = [ct.c_void_p, _i64p]
        lib.sim_trace_ptrs.restype = None
        lib.sim_trace_ptrs.argtypes = [ct.c_void_p,
                                       ct.POINTER(ct.c_void_p)]
        lib.sim_threads_available.restype = ct.c_int
        lib.sim_threads_available.argtypes = []
        lib.mt_selftest.restype = None
        lib.mt_selftest.argtypes = [ct.c_uint32, ct.c_int64, _u32p]
        lib.shuffle_selftest.restype = None
        lib.shuffle_selftest.argtypes = [ct.c_uint32, ct.c_int64,
                                         ct.c_int64, _i64p]
        lib.set_selftest.restype = ct.c_int64
        lib.set_selftest.argtypes = [ct.c_int64, _i64p, _i64p]
        threads_supported = threaded and bool(lib.sim_threads_available())
        _lib = lib
    except Exception as e:  # no compiler, sandboxed cc, bad toolchain, ...
        load_error = f"{type(e).__name__}: {e}"
        _lib = None
    return _lib


# zero-length placeholders for the fault-plan slots when no faults are
# configured (ipar[9] == 0: the kernel never dereferences them)
_NO_F64 = np.zeros(0, dtype=np.float64)
_NO_I64 = np.zeros(0, dtype=np.int64)


def _marshal(ctx):
    """Lower one prepared context into the sim_run argument tuple.

    Returns the 23 arrays in kernel parameter order plus the mutable
    ``cores`` array (migration writes back thread→core bindings).
    """
    tbl = ctx["table"]
    dpar = np.array([
        ctx["hop_lambda"], ctx["hop_lambda_steal"], ctx["lock_time"],
        ctx["deque_lock_time"], ctx["steal_time"], ctx["spawn_time"],
        ctx["wake_latency"], ctx["qop_time"], ctx["cache_refill"],
        ctx["mem_intensity"], ctx["migration_rate"],
    ], dtype=np.float64)
    rdn = ctx["runtime_data_node"]
    fplan = ctx.get("fault_plan")
    ipar = np.array([
        ctx["T"], ctx["num_cores"], ctx["num_nodes"], tbl.n,
        int(ctx["queue_shared"]), int(ctx["child_first"]), ctx["seed"],
        -1 if rdn is None else int(rdn), ctx["root_node0"],
        int(fplan is not None), int(ctx.get("max_steps") or 0),
    ], dtype=np.int64)
    cores = np.ascontiguousarray(ctx["cores"], dtype=np.int64)
    goff, uoff, voff, victims = ctx["vplan"].flat()
    if fplan is None:
        fspeed, fwoff, fwstart, fwend = _NO_F64, _NO_I64, _NO_F64, _NO_F64
    else:
        fspeed, fwoff = fplan.speed, fplan.win_off
        fwstart, fwend = fplan.win_start, fplan.win_end
    args = (dpar, ipar,
            tbl.work_pre, tbl.work_post, tbl.f_root, tbl.f_parent,
            tbl.first_child, tbl.num_children, tbl.first_post, tbl.num_post,
            tbl.parent,
            ctx["core_node_arr"], ctx["node_dist_flat"], ctx["root_dist"],
            cores,
            goff, uoff, voff, victims,
            fspeed, fwoff, fwstart, fwend)
    # always-on aggregate output slots (zeroed; the kernel increments)
    max_hop = ctx.get("max_hop")
    if max_hop is None:
        max_hop = int(ctx["node_dist_flat"].max())
    aggs = (np.zeros(max_hop + 1, dtype=np.int64),
            np.zeros(ctx["num_nodes"], dtype=np.int64),
            np.zeros(ctx["num_nodes"], dtype=np.float64))
    return args, cores, aggs


def _unpack(dout, iout):
    return dict(makespan=float(dout[0]), remote=float(dout[1]),
                total_exec=float(dout[2]), queue_wait=float(dout[3]),
                fault_lost=float(dout[4]), last_t=float(dout[5]),
                steals=int(iout[0]), failed=int(iout[1]),
                reclaimed=int(iout[2]), reexec=int(iout[3]),
                executed=int(iout[4]), steps=int(iout[5]),
                status=int(iout[6]))


class _TraceStorage:
    """Keeps one kernel-allocated trace alive under its numpy views.

    ``TraceBuffer.from_arrays`` retains this as ``_owner``; the malloc'd
    columns are released when the last view drops it.
    """
    __slots__ = ("_free", "_ptr")

    def __init__(self, lib, ptr):
        self._free = lib.sim_trace_free
        self._ptr = ptr

    def close(self):
        ptr, self._ptr = self._ptr, None
        if ptr:
            self._free(ptr)

    def __del__(self):
        self.close()


def _new_trace(lib, ctx):
    """Allocate a kernel trace handle for a prepared context (or None)."""
    if not ctx.get("trace"):
        return None
    tp = lib.sim_trace_new(ctx["table"].n)
    if not tp:
        raise MemoryError("C sim kernel could not allocate a trace buffer")
    return tp


def _wrap_trace(lib, tp):
    """Wrap a filled kernel trace zero-copy into a TraceBuffer."""
    from .trace import ALL_COLS, TraceBuffer
    counts = np.zeros(3, dtype=np.int64)
    lib.sim_trace_counts(tp, counts)
    lens = [int(counts[0])] * 7 + [int(counts[1])] * 5 + [int(counts[2])] * 4
    ptrs = (ct.c_void_p * 16)()
    lib.sim_trace_ptrs(tp, ptrs)
    owner = _TraceStorage(lib, tp)
    arrays = {}
    for (name, dt), p, ln in zip(ALL_COLS, ptrs, lens):
        cty = ct.c_double if dt is np.float64 else ct.c_int64
        arrays[name] = np.ctypeslib.as_array(ct.cast(p, ct.POINTER(cty)),
                                             shape=(ln,))
    return TraceBuffer.from_arrays(arrays, owner=owner)


def _attach_extras(out, aggs, lib, tp):
    # plain lists, matching the py engine's raw dicts: run_batch output
    # slots stay comparable / cheaply picklable
    out["steal_hops"] = [int(x) for x in aggs[0]]
    out["node_tasks"] = [int(x) for x in aggs[1]]
    out["node_remote"] = [float(x) for x in aggs[2]]
    if tp:
        out["trace"] = _wrap_trace(lib, tp)
    return out


def run(ctx) -> dict:
    """Run the C kernel on a prepared simulation context (see runtime)."""
    lib = load()
    assert lib is not None
    args, cores, aggs = _marshal(ctx)
    dout = np.zeros(6, dtype=np.float64)
    iout = np.zeros(7, dtype=np.int64)
    tp = _new_trace(lib, ctx)
    try:
        rc = lib.sim_run(*args, dout, iout, *aggs, tp)
    except BaseException:
        if tp:
            lib.sim_trace_free(tp)
        raise
    if rc != 0:
        if tp:
            lib.sim_trace_free(tp)
        raise MemoryError(f"C sim kernel failed with code {rc}")
    ctx["cores"][:] = [int(c) for c in cores]  # migration mutates bindings
    return _attach_extras(_unpack(dout, iout), aggs, lib, tp)


def run_batch(ctxs, workers: int = 1) -> list:
    """Run many prepared contexts in one kernel call.

    The whole grid executes inside ``sim_run_batch`` — no Python ↔ C
    crossing per config — dispatched across ``workers`` pthreads pulling
    cells from an atomic counter. Each cell writes its own output slot,
    so results are ordered and bit-identical to ``workers=1`` at any
    worker count. Per-config argument arrays are packed as pointer
    tables; everything stays referenced until the call returns.

    Returns one entry per context: the unpacked result dict, or an
    exception object for a cell whose kernel run failed (the rest of
    the batch still completes — callers map these to ``CellError``).
    """
    global _warned_no_threads
    lib = load()
    assert lib is not None
    if not ctxs:
        return []
    if workers > 1 and not threads_supported:
        if not _warned_no_threads:
            _warned_no_threads = True
            warnings.warn(
                "C sim kernel was built without pthread support; "
                "running batch with workers=1",
                RuntimeWarning, stacklevel=2)
        workers = 1
    n = len(ctxs)
    marshalled = [_marshal(ctx) for ctx in ctxs]
    # per-cell trace slots: a kernel trace handle per traced config,
    # NULL (0) for the rest — traced and untraced cells mix freely in
    # one batch, each cell running its compiled-in variant of the loop
    tptrs = []
    try:
        for ctx in ctxs:
            tptrs.append(_new_trace(lib, ctx) or 0)
    except BaseException:
        for tp in tptrs:
            if tp:
                lib.sim_trace_free(tp)
        raise
    # 27 pointer tables, one per kernel parameter position
    ptr_tables = [
        np.ascontiguousarray(
            [m[0][k].ctypes.data for m in marshalled], dtype=np.uintp)
        for k in range(23)
    ] + [
        np.ascontiguousarray(
            [m[2][k].ctypes.data for m in marshalled], dtype=np.uintp)
        for k in range(3)
    ] + [np.ascontiguousarray(tptrs, dtype=np.uintp)]
    dout = np.zeros(6 * n, dtype=np.float64)
    iout = np.zeros(7 * n, dtype=np.int64)
    rcs = np.zeros(n, dtype=np.int64)
    try:
        nfail = lib.sim_run_batch(n, max(int(workers), 1), *ptr_tables,
                                  dout, iout, rcs)
    except BaseException:
        for tp in tptrs:
            if tp:
                lib.sim_trace_free(tp)
        raise
    for ctx, (_, cores, _aggs) in zip(ctxs, marshalled):
        ctx["cores"][:] = [int(c) for c in cores]
    out = []
    for i in range(n):
        if rcs[i] != 0:
            if tptrs[i]:
                lib.sim_trace_free(tptrs[i])
            out.append(MemoryError(
                f"C sim kernel failed with code {int(rcs[i])} "
                f"on batch config {i} of {n}"))
        else:
            out.append(_attach_extras(
                _unpack(dout[6 * i:6 * i + 6], iout[7 * i:7 * i + 7]),
                marshalled[i][2], lib, tptrs[i]))
    assert nfail == sum(isinstance(o, Exception) for o in out)
    return out
