"""The ``Machine`` facade: declarative contexts, runs, and figure grids.

One object ties the whole execution-context layer together::

    m = Machine(topology.sunfire_x4600())

    # compile + cache a context: who runs where, where data lives
    ctx = m.context(threads=16, binding="paper", placement="spill:2")
    r = m.run(wl, "dfwsrpt", context=ctx)

    # or inline — equivalent, the context is cached either way
    r = m.run(wl, "dfwsrpt", threads=16, binding="paper",
              placement="spill:2")

    # a whole paper figure as one declarative call: the cartesian
    # product expands straight into a batched SweepPlan
    g = m.grid(workloads=[wl], schedulers=("wf", "dfwspt", "dfwsrpt"),
               threads=(2, 4, 8, 16), placements=("spill:2",))
    speedups = {k: r.speedup for k, r in g.run().items()}

Contexts are compiled once per (threads, binding, placement,
runtime-data, migration, seed) and cached on the ``Machine``; the
underlying binding/placement lowerings are additionally cached on the
(immutable) topology, so several ``Machine`` instances over one
topology share them. Grid cells with mixed variants (the paper's
baseline-Nanos vs NUMA-aware comparisons) pass ``contexts=``: a mapping
of variant label → context keywords, each variant crossed with every
workload, scheduler, thread count, and seed.
"""

from __future__ import annotations

import collections
import itertools
from typing import Optional, Sequence

from ..topology import Topology
from . import policy
from .context import ExecContext
from .faults import get_faults
from .runtime import SimParams, SimResult, Workload, run_context
from .runtime import serial_time as _serial_time
from .sweep import CellStats, SweepPlan, aggregate

__all__ = ["Machine", "Grid", "GridKey"]


GridKey = collections.namedtuple(
    "GridKey", ["workload", "scheduler", "context", "threads", "seed",
                "faults"], defaults=("none",))
GridKey.__doc__ = """One cell of a :meth:`Machine.grid`.

``workload``/``scheduler`` are names, ``context`` is the variant label
(``bindings × placements`` gives ``"binding/placement"``; an explicit
``contexts=`` mapping gives its keys), ``threads``/``seed`` are ints,
``faults`` is the fault-axis label (``"none"`` when unperturbed).
"""


def _fault_label(specs: tuple) -> str:
    return ",".join(s.name for s in specs) if specs else "none"


def _sched_name(scheduler) -> str:
    return scheduler.name if hasattr(scheduler, "name") else str(scheduler)


class Grid:
    """A compiled figure grid: a batched :class:`SweepPlan` plus the
    :class:`GridKey` of every cell, in plan order. ``store`` (set at
    construction via ``Machine.grid(..., store=)`` or per run) makes
    every run durable — see :meth:`run`."""

    def __init__(self, plan: SweepPlan, keys: list, store=None):
        self.plan = plan
        self.keys = keys
        self.store = store

    def __len__(self) -> int:
        return len(self.keys)

    @staticmethod
    def concat(grids: Sequence["Grid"]) -> "Grid":
        """Fuse several grids into one batch (single engine call) —
        e.g. per-workload grids whose placements differ (``spill:K``
        with K per benchmark) but that belong to one paper figure.
        The merged grid keeps the first non-None ``store``."""
        merged = Grid(SweepPlan(), [])
        for g in grids:
            merged.plan.configs.extend(g.plan.configs)
            merged.keys.extend(g.keys)
            if merged.store is None:
                merged.store = g.store
        return merged

    def run(self, strict: bool = True, workers: "int | None" = None,
            *, store=None, resume: "str | None" = None,
            timeout: "float | None" = None,
            retry=None) -> "dict[GridKey, SimResult]":
        """Run the whole grid in one batched engine call.

        Returns ``{GridKey: SimResult}`` in cell order — bit-identical,
        cell for cell, to looping ``simulate()`` over the same grid,
        at any ``workers`` count (see :func:`~.sweep.run_sweep`).
        Under ``strict=False`` a failing cell maps to a
        :class:`~.sweep.CellError` instead of aborting the batch.

        Durable execution: ``store`` (a :class:`~.store.ResultStore`
        or journal path; default: the grid's own) journals every
        completed cell and replays already-journaled ones, so
        ``resume="campaign.jsonl"`` — sugar for ``store=`` — picks an
        interrupted campaign back up bit-identically, re-simulating
        only the incomplete cells. ``timeout`` (per-cell wall-clock
        seconds) and ``retry`` (a :class:`~.sweep.RetryPolicy`) engage
        the kill-capable supervisor; see :func:`~.sweep.run_sweep`.
        """
        if len(set(self.keys)) != len(self.keys):
            seen: set = set()
            dup = next(k for k in self.keys if k in seen or seen.add(k))
            raise ValueError(
                f"grid has duplicate cells (e.g. {dup}); the result dict "
                "would silently drop them — dedupe schedulers/seeds or "
                "the grids passed to Grid.concat")
        if resume is not None:
            if store is not None:
                raise ValueError("pass either store= or resume=, not both")
            store = resume
        if store is None:
            store = self.store
        return dict(zip(self.keys,
                        self.plan.run(strict=strict, workers=workers,
                                      store=store, timeout=timeout,
                                      retry=retry)))

    def run_stats(self, strict: bool = True,
                  workers: "int | None" = None, *, store=None,
                  resume: "str | None" = None,
                  timeout: "float | None" = None,
                  retry=None) -> "dict[GridKey, CellStats]":
        """Run the grid and fold the Monte-Carlo seed axis into stats.

        Replicas — cells identical up to ``seed`` — aggregate into one
        :class:`~.sweep.CellStats` (mean/std/min/max/CI95 per metric,
        raw per-seed results in ``.results``), keyed by the cell's
        :class:`GridKey` with ``seed=None``. Under ``strict=False``
        failed replicas land in ``.errors`` and the stats cover the
        survivors. Durability knobs as in :meth:`run`.
        """
        res = self.run(strict=strict, workers=workers, store=store,
                       resume=resume, timeout=timeout, retry=retry)
        groups: "dict[GridKey, list]" = {}
        for k, r in res.items():
            groups.setdefault(k._replace(seed=None), []).append(r)
        return {k: aggregate(rs) for k, rs in groups.items()}


class Machine:
    """A topology + cost model with compiled-context caching (see
    module docstring)."""

    def __init__(self, topo: Topology, params: Optional[SimParams] = None,
                 *, bind_seed: int = 0):
        self.topo = topo
        self.params = params or SimParams()
        self.bind_seed = bind_seed
        self._contexts: dict = {}

    def __repr__(self) -> str:
        return (f"Machine({self.topo.name}: {self.topo.num_cores} cores / "
                f"{self.topo.num_nodes} nodes, "
                f"{len(self._contexts)} cached contexts)")

    @property
    def compile_cache(self):
        """The process-wide persistent compile cache handle (or None).

        Every machine — and every grid cell run through one — shares
        this handle, so its ``stats()`` aggregate table/serial/context
        hits across a whole campaign.
        """
        from .compile_cache import get_cache
        return get_cache()

    # ------------------------------------------------------------------
    def context(self, threads: Optional[int] = None, *,
                binding="paper", placement="first_touch",
                runtime_data="local", migration_rate: float = 0.0,
                bind_seed: Optional[int] = None,
                faults=()) -> ExecContext:
        """Compile (and cache) one execution context.

        Args:
          threads: thread count N (optional for explicit core-list
            bindings, which pin their own length).
          binding: :class:`~.context.BindingSpec`, registered name
            (``"paper"``, ``"linear"``, ``"scatter"``, ``"node_fill"``),
            ``"cores:a,b,..."``, or an explicit core sequence.
          placement: :class:`~.context.PlacementSpec`, registered name
            (``"first_touch"``, ``"interleave"``), parametrized form
            (``"spill:K"``, ``"spill:K@N"``, ``"node:N"``,
            ``"nodes:a,b"``), an explicit node / node sequence, or None.
          runtime_data: ``"local"`` (paper's per-thread runtime data),
            ``"master"``, or an explicit node id (baseline Nanos).
          migration_rate: per-task OS thread-migration probability
            (baseline Nanos leaves threads unbound).
          bind_seed: tie-break seed for the ``"paper"`` binding
            (default: the Machine's).
          faults: declarative fault model(s) — :class:`~.faults.FaultSpec`,
            a parametrized string (``"straggler:0.5@2"``, ``"preempt:2@10"``,
            ``"fail:1@30"``), or a sequence composing several. The
            stochastic lowering happens per simulation seed at run time.
        """
        if bind_seed is None:
            bind_seed = self.bind_seed
        binding = tuple(int(c) for c in binding) \
            if isinstance(binding, (list, range)) else binding
        placement = tuple(int(n) for n in placement) \
            if isinstance(placement, (list, range)) else placement
        faults = get_faults(faults)     # normalized: hashable + validated
        key = (threads, binding, placement, runtime_data, migration_rate,
               bind_seed, faults)
        try:
            ctx = self._contexts.get(key)
        except TypeError:           # unhashable spec forms: compile fresh
            key, ctx = None, None
        if ctx is None:
            ctx = ExecContext.compile(
                self.topo, self.params, threads, binding, placement,
                runtime_data, migration_rate, bind_seed, faults)
            if key is not None:
                self._contexts[key] = ctx
        return ctx

    # ------------------------------------------------------------------
    def run(self, workload: Workload, scheduler, *, seed: int = 0,
            context: Optional[ExecContext] = None,
            serial_reference: Optional[float] = None,
            store=None, **context_kwargs) -> SimResult:
        """Simulate ``workload`` under ``scheduler`` on this machine.

        Pass a pre-compiled ``context=`` or any :meth:`context` keywords
        (``threads=16, binding="paper", placement="spill:2"``) inline.
        With ``store=`` (a :class:`~.store.ResultStore` or journal
        path) the cell goes through the durable sweep path: an
        already-journaled result is replayed without simulating, a
        fresh one is committed before returning.
        """
        if context is None:
            context = self.context(**context_kwargs)
        elif context_kwargs:
            raise ValueError("pass either context= or context keywords, "
                             f"not both: {sorted(context_kwargs)}")
        if store is not None:
            plan = SweepPlan()
            plan.add_context(context, workload, scheduler, seed=seed,
                             serial_reference=serial_reference)
            return plan.run(store=store)[0]
        return run_context(context, workload, scheduler, seed,
                           serial_reference)

    def serial_time(self, workload: Workload, *, core: int = 0,
                    placement="first_touch") -> float:
        """Single-thread reference time on ``core`` under ``placement``
        (the paper measures one serial time per benchmark, on the
        boot core with the baseline data placement)."""
        from .context import get_placement
        nodes = get_placement(placement).lower(self.topo, core)
        return _serial_time(self.topo, workload, core, nodes, self.params)

    # ------------------------------------------------------------------
    def grid(self, *, workloads, schedulers, threads=None,
             bindings=("paper",), placements=("first_touch",),
             contexts=None, seeds=(0,), runtime_data="local",
             migration_rate: float = 0.0, faults=None,
             serial_reference=None, store=None) -> Grid:
        """Expand a cartesian product into one batched :class:`Grid`.

        Args:
          workloads: one :class:`Workload`, a sequence of them (keyed by
            ``.name``), or a ``{name: Workload}`` mapping.
          schedulers: scheduler names / specs.
          threads: thread count(s); a single int is broadcast.
          bindings, placements: context variants, crossed with
            everything else; each cell's variant label is
            ``"binding/placement"``.
          contexts: ``{label: {context kwargs}}`` — replaces the
            bindings × placements cross for heterogeneous variants
            (e.g. the paper's baseline-vs-NUMA figures, where binding,
            placement, runtime data, and migration all change together);
            mutually exclusive with non-default bindings/placements.
            A variant may pin its own ``threads``; that variant then
            emits one set of cells at the pinned count instead of one
            per grid-level count.
          seeds: simulation seeds — a sequence of explicit seeds, or an
            int ``n`` as Monte-Carlo shorthand for ``range(n)`` (``n``
            replicas per cell; aggregate with :meth:`Grid.run_stats`).
          runtime_data, migration_rate: defaults for every variant
            (``contexts=`` values override per variant).
          faults: a fault *axis* crossed with everything else — a
            sequence of fault descriptions (each a spec, string, ``()``
            / ``None`` for the unperturbed baseline, or a sequence
            composing several); ``None`` (default) keeps every cell
            fault-free. Cell keys carry the fault label (``"none"``
            for the baseline).
          serial_reference: speedup denominator — ``None`` (per-cell
            default), one float for every cell, or ``{workload name:
            float}`` (the paper's one-serial-per-benchmark convention).
          store: a :class:`~.store.ResultStore` (or journal path) every
            run of the returned grid journals to / replays from — the
            durable-sweep default for this grid (``Grid.run`` can still
            override per call).

        Validation is aggregated: every invalid cell in the expansion —
        unknown scheduler, bad binding/placement, malformed fault — is
        collected and reported in one ``ValueError`` listing each
        offending (workload, scheduler, context) label, instead of
        failing fast on the first.

        Returns a :class:`Grid`; ``.run()`` gives ``{GridKey:
        SimResult}``, bit-identical to the hand-written per-cell loop.
        """
        if isinstance(workloads, Workload):
            workloads = [workloads]
        if isinstance(workloads, dict):
            wl_items = list(workloads.items())
        else:
            wl_items = [(wl.name, wl) for wl in workloads]
        names = [n for n, _ in wl_items]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate workload names {names}; pass a "
                             "{name: workload} mapping to disambiguate")
        if isinstance(schedulers, (str, policy.SchedulerSpec)):
            schedulers = [schedulers]
        if threads is None:
            thread_counts: Sequence = (None,)
        elif isinstance(threads, int):
            thread_counts = (threads,)
        else:
            thread_counts = tuple(threads)
        # Monte-Carlo shorthand: seeds=32 means 32 replicas per cell
        # (seeds 0..31); pass an explicit sequence for specific seeds.
        if isinstance(seeds, int):
            seeds = tuple(range(seeds))

        if contexts is None:
            contexts = {}
            for b, p in itertools.product(bindings, placements):
                label = (f"{getattr(b, 'name', b)}/"
                         f"{getattr(p, 'name', p)}")
                contexts[label] = dict(binding=b, placement=p)
        elif tuple(bindings) != ("paper",) or \
                tuple(placements) != ("first_touch",):
            raise ValueError("pass either contexts= or bindings=/"
                             "placements=, not both — contexts would "
                             "silently win")
        base_kw = dict(runtime_data=runtime_data,
                       migration_rate=migration_rate)
        errors: list = []

        # the fault axis: each entry lowers to a normalized spec tuple
        # + display label; malformed entries join the aggregated report
        fault_axis: list = []
        for f in ([None] if faults is None else faults):
            try:
                specs = get_faults(f)
            except (ValueError, TypeError) as e:
                errors.append(f"fault axis entry {f!r}: {e}")
                continue
            fault_axis.append((specs, _fault_label(specs)))

        def serial_for(name):
            if serial_reference is None:
                return None
            if isinstance(serial_reference, dict):
                return serial_reference[name]
            return serial_reference

        plan = SweepPlan()
        keys: list = []
        for (wl_name, wl), (label, ctx_kw) in itertools.product(
                wl_items, contexts.items()):
            ctx_kw = dict(ctx_kw)
            pinned = ctx_kw.pop("threads", None)
            serial = serial_for(wl_name)
            for T, (fspecs, flabel) in itertools.product(
                    (thread_counts if pinned is None else (pinned,)),
                    fault_axis):
                try:
                    ectx = self.context(
                        T, **{**base_kw, **ctx_kw, "faults": fspecs})
                except (ValueError, TypeError) as e:
                    errors.append(f"grid cell (*/{label}/T={T}"
                                  f"/faults={flabel}): {e}")
                    continue
                for sched, seed in itertools.product(schedulers, seeds):
                    cell = (f"grid cell ({wl_name}/{_sched_name(sched)}/"
                            f"{label}/T={ectx.threads}/seed={seed}"
                            f"/faults={flabel})")
                    cfg = plan.add_context(ectx, wl, sched, seed=seed,
                                           serial_reference=serial,
                                           label=cell, errors=errors)
                    if cfg is not None:
                        keys.append(GridKey(wl_name, _sched_name(sched),
                                            label, ectx.threads, seed,
                                            flabel))
        if errors:
            uniq = list(dict.fromkeys(errors))
            raise ValueError(
                f"{len(errors)} invalid grid cell(s):\n  "
                + "\n  ".join(uniq))
        return Grid(plan, keys, store=store)
