from .runtime import (TaskSpec, Workload, SimParams, SimResult, simulate,
                      serial_time, SCHEDULERS)
from . import bots
