from .runtime import (TaskSpec, Workload, SimParams, SimResult, simulate,
                      serial_time, SCHEDULERS, SchedulerSpec, TaskTable,
                      ensure_table, reset_engine_cache)
from .policy import register, get_spec, compile_victim_plan
from .sweep import SweepConfig, SweepPlan, run_sweep
from . import bots, policy, sweep
