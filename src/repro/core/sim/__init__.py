from .runtime import (TaskSpec, Workload, SimParams, SimResult, simulate,
                      run_context, serial_time, SCHEDULERS, SchedulerSpec,
                      TaskTable, ensure_table, reset_engine_cache)
from .policy import register, get_spec, compile_victim_plan
from .context import (BindingSpec, PlacementSpec, ExecContext, BINDINGS,
                      PLACEMENTS, register_binding, register_placement,
                      get_binding, get_placement)
from .machine import Machine, Grid, GridKey
from .sweep import SweepConfig, SweepPlan, run_sweep
from . import bots, context, machine, policy, sweep
