from .runtime import (TaskSpec, Workload, SimParams, SimResult, SimStalled,
                      simulate, run_context, serial_time, resolve_workers,
                      resolve_timeout, SCHEDULERS, SchedulerSpec, TaskTable,
                      ensure_table, reset_engine_cache)
from .policy import register, get_spec, compile_victim_plan
from .context import (BindingSpec, PlacementSpec, ExecContext, BINDINGS,
                      PLACEMENTS, register_binding, register_placement,
                      get_binding, get_placement)
from .faults import (FaultSpec, FaultPlan, FAULTS, register_fault,
                     get_fault, get_faults, compile_fault_plan)
from .machine import Machine, Grid, GridKey
from .sweep import (SweepConfig, SweepPlan, CellError, CellTimeout,
                    WorkerDied, RetryPolicy, run_sweep,
                    Stat, CellStats, aggregate)
from .store import ResultStore, cell_key, workload_fingerprint
from .trace import TraceBuffer
from .compile_cache import (CompileCache, get_cache, reset_cache,
                            cache_root)
from . import (bots, compile_cache, context, faults, machine, policy,
               store, sweep, trace)
