from .runtime import (TaskSpec, Workload, SimParams, SimResult, simulate,
                      serial_time, SCHEDULERS, TaskTable, ensure_table)
from . import bots
