"""Flat, structure-of-arrays task tables for the NANOS simulator.

A :class:`TaskTable` is the compiled form of a :class:`TaskSpec` tree:
one integer id per task, CSR-style child/post-wave ranges, and parallel
numpy arrays for the per-task scalars. Ids are assigned in BFS order so
that every task's ``children`` and ``post_children`` occupy *contiguous*
id ranges — the runtime then never touches a Python object per task,
only integer indices into these arrays.

Tasks also carry a *memory-profile class* id: the (f_root, f_parent)
pairs of a benchmark tree repeat heavily (a whole combine wave shares
one profile), so the runtime can precompute NUMA penalty lookup tables
per class × node instead of recomputing the penalty formula per task.

Everything here is **iterative** — no recursion — so paper-scale trees
(millions of tasks) compile without hitting the interpreter stack limit;
the CSR index arrays are derived from the per-task child counts with
vectorized cumsum/repeat, never a per-task Python append.
"""

from __future__ import annotations

import numpy as np

__all__ = ["TaskTable", "table_from_arrays", "compile_tree"]


class TaskTable:
    """CSR task tables (see module docstring).

    Attributes (all length ``n`` unless noted):
      work_pre, work_post:    float64 work units (pre-spawn / join).
      f_root, f_parent:       float64 memory-traffic fractions.
      first_child, num_children:  child id range [fc, fc+nc).
      first_post, num_post:       post-wave id range [fp, fp+np).
      parent:                 parent id (-1 for the root).
      cls:                    memory-profile class id per task.
      cls_f_root, cls_f_parent:  (num_classes,) class profiles.
    """

    __slots__ = ("n", "work_pre", "work_post", "f_root", "f_parent",
                 "first_child", "num_children", "first_post", "num_post",
                 "parent", "cls", "cls_f_root", "cls_f_parent",
                 "_serial_cache", "_lists", "_fingerprint")

    def __init__(self, work_pre, work_post, f_root, f_parent,
                 first_child, num_children, first_post, num_post, parent):
        def as_f(a):
            return np.ascontiguousarray(a, dtype=np.float64)

        def as_i(a):
            return np.ascontiguousarray(a, dtype=np.int64)

        self.work_pre = as_f(work_pre)
        self.work_post = as_f(work_post)
        self.f_root = as_f(f_root)
        self.f_parent = as_f(f_parent)
        self.first_child = as_i(first_child)
        self.num_children = as_i(num_children)
        self.first_post = as_i(first_post)
        self.num_post = as_i(num_post)
        self.parent = as_i(parent)
        self.n = int(self.work_pre.shape[0])
        # memory-profile classes: dedupe (f_root, f_parent) pairs. A
        # complex view gives an exact lexicographic pair sort without
        # the much slower np.unique(..., axis=0) path.
        pairs = self.f_root + 1j * self.f_parent
        uniq, inv = np.unique(pairs, return_inverse=True)
        self.cls = np.ascontiguousarray(inv, dtype=np.int64)
        self.cls_f_root = np.ascontiguousarray(uniq.real)
        self.cls_f_parent = np.ascontiguousarray(uniq.imag)
        self._serial_cache: dict = {}
        self._lists = None
        self._fingerprint = None

    @property
    def num_classes(self) -> int:
        return int(self.cls_f_root.shape[0])

    def total_work(self) -> float:
        return float(self.work_pre.sum() + self.work_post.sum())

    def fingerprint(self) -> str:
        """Stable content digest of the compiled workload structure.

        Hashes the defining per-task arrays (work, memory profiles,
        child/post counts — the CSR index arrays and classes are derived
        from these, so they add nothing). Two tables with equal
        fingerprints describe the same computation regardless of how
        they were built (tree compile vs paper-scale direct builder),
        which is exactly the identity the persistent result store keys
        on. Cached: paper-scale tables are tens of MB and the digest is
        a one-time ~100 ms cost per workload.
        """
        if self._fingerprint is None:
            import hashlib
            h = hashlib.blake2b(digest_size=16)
            for arr in (self.work_pre, self.work_post, self.f_root,
                        self.f_parent, self.num_children, self.num_post):
                h.update(arr.tobytes())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    def lists(self):
        """Python-list views of the hot arrays (cached).

        The pure-Python engine indexes these ~10x faster than numpy
        scalar indexing; the C engine uses the arrays directly.
        """
        if self._lists is None:
            self._lists = (
                self.work_pre.tolist(), self.work_post.tolist(),
                self.first_child.tolist(), self.num_children.tolist(),
                self.first_post.tolist(), self.num_post.tolist(),
                self.parent.tolist(), self.cls.tolist(),
            )
        return self._lists

    # every array a table consists of, including the derived CSR
    # indices and profile classes — persisting the derived arrays too
    # lets :meth:`restore` skip the np.unique class dedup (~100 ms at
    # paper scale) and keep mmap'd blobs untouched.
    ARRAY_FIELDS = ("work_pre", "work_post", "f_root", "f_parent",
                    "first_child", "num_children", "first_post",
                    "num_post", "parent", "cls", "cls_f_root",
                    "cls_f_parent")

    def saved_arrays(self) -> dict:
        """All defining + derived arrays, keyed by field name."""
        return {name: getattr(self, name) for name in self.ARRAY_FIELDS}

    @classmethod
    def restore(cls, arrays: dict,
                fingerprint: "str | None" = None) -> "TaskTable":
        """Rebuild a table from :meth:`saved_arrays` output *as is*.

        Trusted-restore path for the compile cache: the arrays (often
        read-only memory maps) are adopted without the ``__init__``
        normalization or class recompute, and a known fingerprint is
        pre-seeded so a restored paper-scale table never hashes its
        tens of MB just to be identified.
        """
        missing = [f for f in cls.ARRAY_FIELDS if f not in arrays]
        if missing:
            raise ValueError(f"table restore missing arrays: {missing}")
        self = object.__new__(cls)
        for name in cls.ARRAY_FIELDS:
            setattr(self, name, arrays[name])
        self.n = int(self.work_pre.shape[0])
        self._serial_cache = {}
        self._lists = None
        self._fingerprint = fingerprint
        return self


def table_from_arrays(work_pre, work_post, f_root, f_parent,
                      num_children, num_post) -> TaskTable:
    """Build a table from per-task scalars + child/post counts.

    The tasks must already be in BFS id order (each task's children
    followed by its post wave form one contiguous block, blocks laid out
    in parent-id order). The CSR index arrays follow from the counts:
    ``first_child[i] = 1 + sum(blocks[:i])`` and the parent of every id
    in block *i* is *i* — both fully vectorized.
    """
    nc = np.ascontiguousarray(num_children, dtype=np.int64)
    npw = np.ascontiguousarray(num_post, dtype=np.int64)
    n = nc.shape[0]
    blocks = nc + npw
    fc = np.empty(n, dtype=np.int64)
    fc[0] = 1
    if n > 1:
        np.cumsum(blocks[:-1], out=fc[1:])
        fc[1:] += 1
    fpw = fc + nc
    parent = np.empty(n, dtype=np.int64)
    parent[0] = -1
    parent[1:] = np.repeat(np.arange(n, dtype=np.int64), blocks)
    return TaskTable(work_pre, work_post, f_root, f_parent,
                     fc, nc, fpw, npw, parent)


def compile_tree(root) -> TaskTable:
    """Compile a :class:`TaskSpec` tree into a :class:`TaskTable`.

    Iterative BFS: a single pass collects the specs in id order, then
    the scalar arrays are gathered with ``np.fromiter`` and the CSR
    indices derived vectorized.
    """
    specs = [root]
    i = 0
    while i < len(specs):
        s = specs[i]
        if s.children:
            specs.extend(s.children)
        if s.post_children:
            specs.extend(s.post_children)
        i += 1
    from operator import attrgetter
    n = len(specs)
    wp = np.fromiter(map(attrgetter("work_pre"), specs), np.float64, n)
    wpo = np.fromiter(map(attrgetter("work_post"), specs), np.float64, n)
    fr = np.fromiter(map(attrgetter("f_root"), specs), np.float64, n)
    fp = np.fromiter(map(attrgetter("f_parent"), specs), np.float64, n)
    nc = np.fromiter(map(len, map(attrgetter("children"), specs)),
                     np.int64, n)
    npw = np.fromiter(map(len, map(attrgetter("post_children"), specs)),
                      np.int64, n)
    return table_from_arrays(wp, wpo, fr, fp, nc, npw)
