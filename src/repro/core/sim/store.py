"""Persistent, content-addressed result store for simulation sweeps.

Paper-scale campaigns (32-seed Monte-Carlo replicas over 1M+-task
tables) take long enough that a hung cell, a killed worker, or an
interrupted process must not cost the whole grid. This module gives
:func:`~.sweep.run_sweep` a durable substrate:

* :func:`cell_key` — a stable digest of *everything that determines a
  cell's result*: the topology fingerprint, the compiled task table,
  the lowered execution context (binding, placement, runtime data,
  migration, faults, cost-model constants), the scheduler policy
  fields, the seed, and the serial reference the speedup is computed
  against. Two cells with equal keys are bit-identical by construction
  (the engines are deterministic in exactly these inputs), so a stored
  result can stand in for a simulation — on either engine.
* :class:`ResultStore` — an append-only JSONL journal of completed
  :class:`~.runtime.SimResult` values plus an in-memory index. Appends
  are one ``write()`` + ``flush()`` of a single ``\\n``-terminated line
  (atomic enough for a single writer: a crash can only tear the *last*
  line, and loading tolerates a torn tail), so an interrupted campaign
  resumes from its journal losing at most the cell that was mid-commit.

Only *successes* are journaled. Failures (stalls, engine errors,
timeouts) are represented in the run's return value but never
persisted, so a resumed campaign always re-attempts them.

Event traces (``SimResult.trace``, present under ``SimParams(trace=
True)``) do not belong in a JSONL line: a paper-scale trace is tens of
MB of flat arrays. ``put`` spills them to *sidecar* ``.npz`` files —
``<journal stem>.traces/<cell key>.npz`` — and journals the result with
``trace=None``; :meth:`ResultStore.get_trace` loads a sidecar back by
cell key (the :mod:`analysis` loader's journal entry point). Sidecar
writes are atomic (tmp + rename) and first-write-wins like the journal.

Floats round-trip exactly: ``json`` serializes Python floats via
``repr``, which is shortest-round-trip, and parses back to the same
IEEE-754 double — a replayed result is bit-identical to the simulated
one, which the resume tests pin.

Journal format (one JSON document per line)::

    {"format": "repro-sim-store", "version": 1}          # header
    {"k": "<32-hex cell key>", "r": {...SimResult fields...}}
    ...
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings

from .runtime import SimResult, ensure_table

__all__ = ["ResultStore", "cell_key", "workload_fingerprint"]

_HEADER = {"format": "repro-sim-store", "version": 1}


def workload_fingerprint(workload) -> str:
    """Content digest of a workload: the compiled table + µ.

    The table fingerprint covers the task structure (work, memory
    profiles, tree shape); ``mem_intensity`` scales every NUMA penalty
    and lives on the workload, not the table. The workload *name* is
    excluded — a renamed but identical benchmark hits the same cells.
    """
    tbl = ensure_table(workload)
    return hashlib.blake2b(
        (tbl.fingerprint() + repr(float(workload.mem_intensity))).encode(),
        digest_size=16).hexdigest()


def cell_key(ectx, workload, spec, seed: int,
             serial: "float | None" = None) -> str:
    """Stable key of one sweep cell's result (see module docstring).

    ``spec`` contributes its three *policy* fields, not its name: two
    registered names with identical (queue, spawn, victim) run the same
    program. ``serial`` is the speedup denominator actually used —
    ``SimResult.speedup`` depends on it, so cells differing only in
    their serial reference must not collide.
    """
    material = (ectx.fingerprint(), workload_fingerprint(workload),
                spec.queue, spec.spawn, spec.victim, int(seed),
                None if serial is None else float(serial))
    return hashlib.blake2b(repr(material).encode(),
                           digest_size=16).hexdigest()


class ResultStore:
    """Append-only JSONL journal of completed cell results.

    Open (or create) a journal at ``path``; existing entries are loaded
    into the in-memory index, tolerating a torn final line from a
    killed writer. ``sync=True`` adds an ``fsync`` per commit for
    crash-consistency against power loss (the default survives process
    death, which is the failure mode sweeps actually hit).

    First write wins: a ``put`` under an already-present key is a
    no-op, so concurrent or repeated campaigns can share a journal
    without rewriting history (all writers compute bit-identical
    results for a given key, so which one landed is immaterial).
    """

    def __init__(self, path: "str | os.PathLike", sync: bool = False):
        self.path = os.fspath(path)
        self.sync = sync
        self.hits = 0            # get() calls that found a result
        self._index: "dict[str, SimResult]" = {}
        self._load()
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")
        if self._fh.tell() == 0:
            self._commit(json.dumps(_HEADER, separators=(",", ":")))

    # ------------------------------------------------------------------
    def _load(self) -> None:
        try:
            with open(self.path, "r", encoding="utf-8") as fh:
                raw = fh.read()
        except FileNotFoundError:
            return
        lines = raw.split("\n")
        torn = lines.pop() if lines and not raw.endswith("\n") else ""
        bad = 0
        for line in lines:
            if not line:
                continue
            try:
                doc = json.loads(line)
                if "k" not in doc:
                    continue     # header / future metadata line
                res = SimResult(**doc["r"])
                # JSON round-trips the aggregate tuples as lists;
                # normalize so a replayed result matches a fresh one
                res.steal_hops = tuple(res.steal_hops)
                res.node_tasks = tuple(res.node_tasks)
                res.node_remote = tuple(res.node_remote)
            except (ValueError, TypeError):
                bad += 1
                continue
            self._index.setdefault(doc["k"], res)
        if torn or bad:
            what = []
            if torn:
                what.append("a torn final line (interrupted write)")
            if bad:
                what.append(f"{bad} malformed line(s)")
            warnings.warn(
                f"result store {self.path}: skipped {' and '.join(what)}; "
                f"{len(self._index)} entries loaded",
                RuntimeWarning, stacklevel=3)
        if torn:
            # drop the torn tail so the next append starts clean and a
            # later load doesn't re-report the fragment as malformed
            os.truncate(self.path, len(raw.encode()) - len(torn.encode()))

    def _commit(self, line: str) -> None:
        self._fh.write(line + "\n")
        self._fh.flush()
        if self.sync:
            os.fsync(self._fh.fileno())

    # ------------------------------------------------------------------
    def get(self, key: str) -> "SimResult | None":
        res = self._index.get(key)
        if res is not None:
            self.hits += 1
        return res

    def put(self, key: str, result: SimResult) -> None:
        if key in self._index:
            return               # first write wins
        tr = getattr(result, "trace", None)
        if tr is not None:
            # spill the event trace to its sidecar and journal the
            # result without it (a trace is MBs of arrays, not a line)
            self._spill_trace(key, tr)
            result = dataclasses.replace(result, trace=None)
        self._index[key] = result
        self._commit(json.dumps(
            {"k": key, "r": dataclasses.asdict(result)},
            separators=(",", ":")))

    # ------------------------------------------------------------------
    def trace_dir(self) -> str:
        """Sidecar directory for spilled event traces."""
        stem = self.path
        if stem.endswith(".jsonl"):
            stem = stem[:-len(".jsonl")]
        return stem + ".traces"

    def trace_path(self, key: str) -> str:
        """Sidecar ``.npz`` path for ``key`` (may not exist)."""
        return os.path.join(self.trace_dir(), f"{key}.npz")

    def _spill_trace(self, key: str, tr) -> None:
        path = self.trace_path(key)
        if os.path.exists(path):
            return               # first write wins, like the journal
        d = self.trace_dir()
        os.makedirs(d, exist_ok=True)
        import tempfile
        fd, tmp = tempfile.mkstemp(suffix=".npz", dir=d)
        os.close(fd)
        try:
            tr.save_npz(tmp)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def get_trace(self, key: str):
        """Load the spilled event trace for ``key``, or None."""
        path = self.trace_path(key)
        if not os.path.exists(path):
            return None
        from .trace import TraceBuffer
        return TraceBuffer.load_npz(path)

    def keys(self):
        """Journaled cell keys (insertion order)."""
        return iter(self._index)

    def items(self):
        """(key, SimResult) pairs for every journaled cell."""
        return self._index.items()

    def __contains__(self, key: str) -> bool:
        return key in self._index

    def __len__(self) -> int:
        return len(self._index)

    def __repr__(self) -> str:
        return (f"ResultStore({self.path!r}: {len(self._index)} entries, "
                f"{self.hits} hits)")

    def flush(self) -> None:
        self._fh.flush()
        if self.sync:
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "ResultStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
