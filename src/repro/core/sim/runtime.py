"""Discrete-event simulator of the NANOS task runtime on a NUMA machine.

This is the *paper-faithful* reproduction layer: OpenMP-style tasking with
per-thread LIFO task pools, the three stock Nanos schedulers the paper
benchmarks against (breadth-first, Cilk-based, work-first), and the two
NUMA-aware schedulers the paper contributes (DFWSPT, DFWSRPT), running on
an explicit hop-distance topology with a first-touch memory model.

Machine/cost model (constants in :class:`SimParams`):

* executing a task on core ``c`` costs::

      work * (1 + mem_intensity * hop_lambda *
                 (f_root * d(c, root_data_node) + f_parent * d(c, parent_exec_node)))

  ``root_data_node`` is where the benchmark's big arrays were allocated —
  the node of the *master thread's* core under Linux first-touch (paper
  §V.B); ``parent_exec_node`` is where the task's parent ran (temporaries
  + hot caches), so depth-first execution on the same core is free of the
  second term, exactly the locality the paper exploits.

* the breadth-first scheduler's single shared queue is a serialized
  resource (a lock): every push/pop waits for the previous holder. With
  millions of tiny tasks this serialization collapses scalability — the
  paper's FFT observation (speedup 4.43x@6 cores → 2.39x@16).

* a steal probe on a victim at ``d`` hops costs
  ``steal_time * (1 + hop_lambda_steal * d)`` — remote queue metadata
  lives in the victim's node memory.

The simulator is deterministic given (workload, params, seed).

Engine architecture (this module is the public API):

* :class:`TaskSpec` trees are compiled once per workload into a flat
  CSR :class:`TaskTable` (structure-of-arrays; see ``table.py``) and
  cached on the :class:`Workload`. Paper-scale workloads (millions of
  tasks) are built directly as tables without ever materializing a
  Python tree (see ``bots.make(name, "paper")``).
* schedulers are **declarative policies**, not engine branches: a
  :class:`~.policy.SchedulerSpec` names a queue discipline
  (shared-locked vs. per-thread LIFO), a spawn order (child-first vs.
  parent-first) and a victim policy, and ``policy.compile_victim_plan``
  lowers the victim policy once per (topology, binding) into group/unit
  arrays that both engines consume identically. ``SCHEDULERS`` is the
  registry mapping name → spec; register a new scheduler with
  ``policy.register(SchedulerSpec(...))`` and every engine, benchmark
  driver, and sweep picks it up — no engine edits (see ``policy.py``).
* the event loop runs either in a compiled C kernel (``_csim``;
  built on demand, ~100x the seed engine) or a pure-Python flat loop
  (``_engine_py``). Both preserve the seed engine's behavior exactly —
  same rng draw sequence, same event ordering, same float association —
  and are pinned by golden-parity fixtures recorded from the seed.
  Select with ``REPRO_SIM_ENGINE={auto,c,py}`` (default auto; the
  choice is validated once and cached until the variable changes —
  ``reset_engine_cache()`` drops it, and ``SimResult.engine`` reports
  the engine that actually ran).
* the *execution context* — who runs where, where data lives — is
  declarative too: a :class:`~.context.BindingSpec` (thread→core
  mapping; ``"paper"`` priority-based, ``"linear"``, ``"scatter"``,
  ``"node_fill"``, explicit lists) and a
  :class:`~.context.PlacementSpec` (root-array placement;
  ``"first_touch"``, ``"spill:K"``, ``"spill:K@N"``, ``"interleave"``,
  explicit nodes) lower once per (topology, T, seed) into the cached
  core/node tuples of an immutable :class:`~.context.ExecContext`.
  :func:`run_context` is the engine entry point that consumes one;
  the positional :func:`simulate` below is a thin shim that wraps its
  raw arguments into an explicit context. The
  :class:`~.machine.Machine` facade compiles, caches, and sweeps
  contexts: ``Machine(topo).context(threads=16, binding="paper",
  placement="spill:2")``.
* many-config grids (the paper's figure sweeps) should go through
  :mod:`.sweep`: a ``SweepPlan`` shares every compiled artifact across
  configs and the C path runs the whole batch in one call —
  ``Machine.grid(...)`` expands a cartesian product straight into one.
"""

from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Optional, Sequence

import numpy as np

from ..topology import Topology, lazy_cache
from . import _csim, _engine_py, policy
from .context import ExecContext
from .faults import compile_fault_plan
from .policy import SCHEDULERS, SchedulerSpec
from .table import TaskTable, compile_tree

__all__ = [
    "TaskSpec", "Workload", "SimParams", "SimResult", "SimStalled",
    "simulate", "run_context", "serial_time", "resolve_workers",
    "resolve_timeout", "SCHEDULERS", "SchedulerSpec", "TaskTable",
    "ensure_table", "reset_engine_cache",
]


class SimStalled(RuntimeError):
    """The event loop did not complete the workload.

    ``reason`` is ``"watchdog"`` (the step-count budget — see
    ``SimParams.max_steps`` — was exhausted: a hung loop became this
    diagnosable error instead of spinning forever) or ``"stranded"``
    (the loop drained with tasks left unexecuted — work was lost, e.g.
    by a fault model no thread survived to clean up after).
    ``scheduler``, ``last_t`` (last event time), ``steps``, and the
    optional sweep ``cell`` label identify the offending run.
    """

    def __init__(self, reason: str, scheduler: str, last_t: float,
                 steps: int, executed: int, tasks: int,
                 cell: "str | None" = None):
        self.reason = reason
        self.scheduler = scheduler
        self.last_t = last_t
        self.steps = steps
        self.executed = executed
        self.tasks = tasks
        self.cell = cell
        where = f"{cell}: " if cell else ""
        if reason == "watchdog":
            msg = (f"{where}simulation stalled under scheduler "
                   f"{scheduler!r}: step watchdog fired after {steps} "
                   f"events at t={last_t:.6g} "
                   f"({executed}/{tasks} tasks executed)")
        else:
            msg = (f"{where}simulation under scheduler {scheduler!r} "
                   f"drained with stranded work: {executed}/{tasks} "
                   f"tasks executed, last event t={last_t:.6g}")
        super().__init__(msg)

    def with_cell(self, cell: str) -> "SimStalled":
        """A copy naming the sweep cell the stall occurred in."""
        return SimStalled(self.reason, self.scheduler, self.last_t,
                          self.steps, self.executed, self.tasks, cell)


@dataclasses.dataclass
class TaskSpec:
    """A node of the benchmark's task tree.

    work_pre:  compute units before spawning children.
    work_post: compute units of the join continuation (0 = no taskwait).
    f_root:    fraction of this task's memory traffic hitting the root
               arrays (allocated by master at startup, first-touch).
    f_parent:  fraction hitting the parent's temporaries / caches.
    children:  sub-tasks spawned after work_pre.
    """
    work_pre: float
    work_post: float = 0.0
    f_root: float = 0.0
    f_parent: float = 0.0
    children: list["TaskSpec"] = dataclasses.field(default_factory=list)
    # spawned when all ``children`` complete (BOTS-style parallel combine
    # wave after a taskwait); ``work_post`` runs after *these* complete.
    post_children: list["TaskSpec"] = dataclasses.field(default_factory=list)

    def count(self) -> int:
        stack, n = [self], 0
        while stack:
            t = stack.pop()
            n += 1
            stack.extend(t.children)
            stack.extend(t.post_children)
        return n

    def total_work(self) -> float:
        stack, w = [self], 0.0
        while stack:
            t = stack.pop()
            w += t.work_pre + t.work_post
            stack.extend(t.children)
            stack.extend(t.post_children)
        return w


@dataclasses.dataclass
class Workload:
    name: str
    root: Optional[TaskSpec]
    mem_intensity: float  # µ — how memory-bound the benchmark is (0..~1)
    # compiled flat form; populated lazily from ``root`` (cached), or
    # directly by the paper-scale builders (which have no tree).
    table: Optional[TaskTable] = None


def ensure_table(workload: Workload) -> TaskTable:
    """Compile (once) and return the workload's flat task table."""
    tbl = workload.table
    if tbl is None:
        if workload.root is None:
            raise ValueError(f"workload {workload.name!r} has neither a "
                             "task tree nor a compiled table")
        tbl = compile_tree(workload.root)
        workload.table = tbl
    return tbl


@dataclasses.dataclass(frozen=True)
class SimParams:
    hop_lambda: float = 0.4         # NUMA factor slope per hop (exec)
    hop_lambda_steal: float = 2.0   # per-hop slope for steal probes
    lock_time: float = 0.25         # serialized shared-queue op cost
    deque_lock_time: float = 0.4    # victim-deque serialized op cost
    steal_time: float = 1.5         # base steal probe cost
    spawn_time: float = 0.02        # per-child task-creation overhead
    wake_latency: float = 0.05      # parked thread wake-up latency
    qop_time: float = 0.05          # local task-pool push/pop cost
    cache_refill: float = 4.0       # work units lost per thread migration
    # event-loop watchdog budget; <= 0 sizes it automatically from the
    # workload (generous — legitimate runs never trip it). A hung loop
    # raises SimStalled instead of spinning forever.
    max_steps: int = 0
    # batch worker count for sweeps (C pthread pool / py process pool);
    # <= 0 defers to REPRO_SIM_WORKERS, then os.cpu_count(). 1 is the
    # serial path. Results are bit-identical at any worker count.
    workers: int = 0
    # record a full event trace (see trace.TraceBuffer) on SimResult.trace.
    # Purely observational: metrics are bit-identical traced vs untraced
    # (the untraced hot path carries no per-event bookkeeping), and the
    # flag is excluded from ExecContext.fingerprint() like ``workers``.
    trace: bool = False


@dataclasses.dataclass
class SimResult:
    makespan: float
    serial_time: float
    speedup: float
    tasks: int
    steals: int
    failed_probes: int
    remote_work_fraction: float  # share of exec time that was NUMA penalty
    queue_wait: float            # total time spent waiting on the bf lock
    # ---- fault accounting (all zero on fault-free runs) ----
    reclaimed: int = 0           # tasks made re-stealable by offline threads
    reexec: int = 0              # executions aborted mid-run and re-executed
    fault_lost: float = 0.0      # partial work discarded by preemption/failure
    # ---- always-on locality aggregates (cheap O(1) counters; excluded
    # from equality like ``engine`` so golden fixtures stay valid) ----
    # successful steals by hop distance: steal_hops[d] = steals at d hops
    steal_hops: tuple = dataclasses.field(default=(), compare=False)
    # per exec node: tasks executed there / NUMA penalty time paid there
    node_tasks: tuple = dataclasses.field(default=(), compare=False)
    node_remote: tuple = dataclasses.field(default=(), compare=False)
    # which engine actually ran ('c' or 'py'); excluded from equality so
    # cross-engine parity checks compare metrics only.
    engine: str = dataclasses.field(default="", compare=False)
    # full event trace (a trace.TraceBuffer) when SimParams(trace=True);
    # stripped to a sidecar .npz by ResultStore.put.
    trace: "object | None" = dataclasses.field(default=None, compare=False,
                                               repr=False)


def _root_data_setup(topo: Topology, core: int, root_data_nodes):
    """Normalize ``root_data_nodes`` and compute per-node mean distance.

    None → the node of ``core`` (Linux first-touch by the master thread);
    int → a single explicit node. Large inputs spill over several nodes
    and pages are interleaved over the spill set, so the access distance
    is the mean over it (paper §V.B). The mean-distance vector is cached
    on the topology per spill set — sweeps hit the same handful of
    placements across hundreds of configs.
    """
    if root_data_nodes is None:
        root_data_nodes = [int(topo.core_node[core])]
    elif isinstance(root_data_nodes, (int, np.integer)):
        root_data_nodes = [int(root_data_nodes)]
    else:
        root_data_nodes = [int(n) for n in root_data_nodes]
    cache = lazy_cache(topo, "_root_dist_cache")
    key = tuple(root_data_nodes)
    root_dist = cache.get(key)
    if root_dist is None:
        root_dist = np.ascontiguousarray(
            topo.node_distance[:, root_data_nodes].mean(axis=1),
            dtype=np.float64)
        cache[key] = root_dist
    return root_data_nodes, root_dist


def serial_time(topo: Topology, workload: Workload, core: int,
                root_data_nodes, params: "SimParams | None" = None) -> float:
    """Single-thread execution time on ``core`` under the NUMA cost model.

    Depth-first on one core ⇒ parent data always local (d_parent = 0);
    only the root-array distance (incl. spill interleave) is paid.

    The traversal runs over the compiled task table in the same stack
    order as the original tree walk (bit-identical sum), and the result
    is cached on the table per (distance, µ, λ) key — benchmark drivers
    call this with identical arguments hundreds of times — *and* in the
    persistent :mod:`~.compile_cache` keyed by (table fingerprint,
    topology fingerprint, root distance, µ, λ), so the full serial walk
    runs once per machine, ever (JSON round-trips the float exactly).
    """
    p = params or SimParams()
    _, root_dist = _root_data_setup(topo, core, root_data_nodes)
    d_root = float(root_dist[topo.core_node[core]])
    tbl = ensure_table(workload)
    key = (d_root, workload.mem_intensity, p.hop_lambda)
    cached = tbl._serial_cache.get(key)
    if cached is not None:
        return cached
    # consult the persistent cache *before* tbl.lists() — materializing
    # the list views of a paper-scale table costs ~1 s by itself
    from .compile_cache import digest_key, get_cache
    pcache = get_cache()
    pkey = None
    if pcache is not None:
        pkey = digest_key("serial", tbl.fingerprint(), topo.fingerprint(),
                          d_root, float(workload.mem_intensity),
                          float(p.hop_lambda))
        stored = pcache.get_serial(pkey)
        if stored is not None:
            tbl._serial_cache[key] = stored
            return stored
    mu_lam = workload.mem_intensity * p.hop_lambda
    coef = [(mu_lam * fr) * d_root for fr in tbl.cls_f_root.tolist()]
    wp_l, wpo_l, fc_l, nc_l, fpw_l, npw_l, _, cls_l = tbl.lists()
    total = 0.0
    stack = [0]
    pop = stack.pop
    extend = stack.extend
    while stack:
        i = pop()
        total += (wp_l[i] + wpo_l[i]) * (1.0 + coef[cls_l[i]])
        nk = nc_l[i]
        if nk:
            base = fc_l[i]
            extend(range(base, base + nk))
        kp = npw_l[i]
        if kp:
            base = fpw_l[i]
            extend(range(base, base + kp))
    tbl._serial_cache[key] = total
    if pcache is not None:
        pcache.put_serial(pkey, total)
    return total


def resolve_workers(workers: "int | None" = None,
                    params: "SimParams | None" = None) -> int:
    """Resolve the batch worker count (always >= 1).

    Precedence: explicit ``workers`` argument > ``SimParams.workers``
    (when > 0) > the ``REPRO_SIM_WORKERS`` env var > ``os.cpu_count()``.
    """
    if workers is not None:
        return max(int(workers), 1)
    if params is not None and params.workers > 0:
        return int(params.workers)
    env = os.environ.get("REPRO_SIM_WORKERS")
    if env is not None and env.strip():
        try:
            return max(int(env), 1)
        except ValueError:
            raise ValueError(
                f"REPRO_SIM_WORKERS={env!r}: expected an integer") from None
    return os.cpu_count() or 1


def resolve_timeout(timeout: "float | None" = None) -> "float | None":
    """Resolve the per-cell wall-clock timeout (seconds, or None).

    Precedence: explicit ``timeout`` argument > the ``REPRO_SIM_TIMEOUT``
    env var > None (no deadline). ``0`` or negative disables. A timeout
    routes batches through the supervised fork pool (see
    :func:`~.sweep.run_sweep`) so a wedged C call or dead worker can be
    killed, not merely observed.
    """
    if timeout is not None:
        return float(timeout) if timeout > 0 else None
    env = os.environ.get("REPRO_SIM_TIMEOUT")
    if env is not None and env.strip():
        try:
            t = float(env)
        except ValueError:
            raise ValueError(
                f"REPRO_SIM_TIMEOUT={env!r}: expected seconds") from None
        return t if t > 0 else None
    return None


# (env value, resolved engine); revalidated only when the variable
# changes, so the per-simulate hot path is one os.environ read.
_engine_cache: "tuple[str, str] | None" = None


def reset_engine_cache() -> None:
    """Drop the cached engine choice (tests / after toolchain changes).

    Also forgets a failed C-kernel load attempt, so a compiler that
    appeared after the first call gets a fresh chance.
    """
    global _engine_cache
    _engine_cache = None
    _csim.reset()


def _select_engine() -> str:
    global _engine_cache
    mode = os.environ.get("REPRO_SIM_ENGINE", "auto")
    cached = _engine_cache
    if cached is not None and cached[0] == mode:
        return cached[1]
    if mode == "py":
        engine = "py"
    elif mode == "c":
        if _csim.load() is None:
            raise RuntimeError(
                f"REPRO_SIM_ENGINE=c but the kernel is unavailable: "
                f"{_csim.load_error}")
        engine = "c"
    elif mode == "auto":
        if _csim.load() is not None:
            engine = "c"
        else:
            # graceful degradation: no compiler / failed build falls
            # back to the (bit-identical, slower) Python engine. Warn
            # once — the choice is cached until the env var changes or
            # reset_engine_cache() is called.
            warnings.warn(
                "C simulation kernel unavailable "
                f"({_csim.load_error}); falling back to the pure-Python "
                "engine (identical results, ~100x slower). Install a C "
                "compiler and call reset_engine_cache() to retry.",
                RuntimeWarning, stacklevel=3)
            engine = "py"
    else:
        raise ValueError(
            f"REPRO_SIM_ENGINE={mode!r}: expected 'auto', 'c', or 'py'")
    _engine_cache = (mode, engine)
    return engine


def _prepare_ctx(ectx: ExecContext,
                 workload: Workload,
                 spec: SchedulerSpec,
                 seed: int) -> dict:
    """Lower one :class:`ExecContext` into an engine-ready dict.

    Every compiled artifact is cached where sweeps can share it: the
    task table on the workload, the victim plan and root-distance
    vectors on the topology, the serial reference on the table.
    """
    topo = ectx.topo
    p = ectx.params
    cores = [int(c) for c in ectx.thread_cores]
    tbl = ensure_table(workload)
    root_data_nodes, root_dist = _root_data_setup(topo, cores[0],
                                                  ectx.root_data_nodes)
    ctx: dict = dict(
        table=tbl, T=len(cores), cores=cores, seed=seed,
        queue_shared=spec.queue == "shared",
        child_first=spec.spawn == "child_first",
        vplan=policy.compile_victim_plan(spec, topo, cores),
        num_cores=topo.num_cores, num_nodes=topo.num_nodes,
        core_node_arr=np.ascontiguousarray(topo.core_node, dtype=np.int64),
        node_dist_flat=np.ascontiguousarray(topo.node_distance,
                                            dtype=np.int64).ravel(),
        root_dist=root_dist,
        root_data_nodes=root_data_nodes,
        root_node0=int(root_data_nodes[0]),
        runtime_data_node=ectx.runtime_data_node,
        migration_rate=ectx.migration_rate,
        mem_intensity=workload.mem_intensity,
        hop_lambda=p.hop_lambda, hop_lambda_steal=p.hop_lambda_steal,
        lock_time=p.lock_time, deque_lock_time=p.deque_lock_time,
        steal_time=p.steal_time, spawn_time=p.spawn_time,
        wake_latency=p.wake_latency, qop_time=p.qop_time,
        cache_refill=p.cache_refill,
    )
    # fault plan: compiled (and cached on the topology) per (specs,
    # binding, seed) from a dedicated RNG stream — the engine rng below
    # is untouched, keeping fault-free runs golden-exact.
    faults = getattr(ectx, "faults", ())
    fplan = compile_fault_plan(faults, topo, cores, seed) if faults else None
    ctx["fault_plan"] = fplan
    ms = getattr(p, "max_steps", 0)
    if ms <= 0:
        nw = fplan.n_windows if fplan is not None else 0
        ms = 10_000 + 1_000 * len(cores) + 50 * (tbl.n + nw)
    ctx["max_steps"] = int(ms)
    ctx["scheduler_name"] = spec.name
    # trace capture flag + hop-histogram width (max hop distance + 1);
    # the always-on aggregates need the width even when tracing is off.
    ctx["trace"] = bool(getattr(p, "trace", False))
    ctx["max_hop"] = int(ctx["node_dist_flat"].max())
    # Fresh per-config stream, seeded exactly as the seed engine did.
    # Victim-plan compilation consumes no draws, so the engine always
    # starts from RandomState(seed)'s initial state.
    ctx["rng"] = np.random.RandomState(seed)
    return ctx


def _finish_result(ctx: dict, out: dict, serial: float,
                   engine: str) -> SimResult:
    status = out.get("status", 0)
    if status:
        raise SimStalled("watchdog" if status == 1 else "stranded",
                         ctx.get("scheduler_name", "?"),
                         out.get("last_t", 0.0), out.get("steps", 0),
                         out.get("executed", 0), ctx["table"].n)
    makespan = out["makespan"]
    rf = out["remote"] / max(out["total_exec"], 1e-12)
    tr = out.get("trace")
    if tr is not None:
        tr.meta.update(
            scheduler=ctx.get("scheduler_name", "?"), seed=int(ctx["seed"]),
            engine=engine, threads=int(ctx["T"]),
            num_nodes=int(ctx["num_nodes"]), num_cores=int(ctx["num_cores"]),
            tasks=int(ctx["table"].n), makespan=float(makespan))
    return SimResult(
        makespan=makespan,
        serial_time=serial,
        speedup=serial / makespan if makespan > 0 else float("nan"),
        tasks=ctx["table"].n,
        steals=out["steals"],
        failed_probes=out["failed"],
        remote_work_fraction=rf,
        queue_wait=out["queue_wait"],
        reclaimed=out.get("reclaimed", 0),
        reexec=out.get("reexec", 0),
        fault_lost=out.get("fault_lost", 0.0),
        steal_hops=tuple(int(x) for x in out.get("steal_hops", ())),
        node_tasks=tuple(int(x) for x in out.get("node_tasks", ())),
        node_remote=tuple(float(x) for x in out.get("node_remote", ())),
        engine=engine,
        trace=out.get("trace"),
    )


def run_context(ectx: ExecContext,
                workload: Workload,
                scheduler: "str | SchedulerSpec",
                seed: int = 0,
                serial_reference: float | None = None) -> SimResult:
    """Run ``workload`` under a compiled :class:`ExecContext`.

    This is the engine entry point everything funnels through:
    :func:`simulate` wraps its raw arguments into a context,
    :meth:`.machine.Machine.run` passes cached ones, and
    :func:`.sweep.run_sweep` batches many.

    ``serial_reference`` overrides the speedup denominator; the default
    is :func:`serial_time` on the context's master core with the
    context's data placement. Pass one common value when comparing
    variants like the paper does.
    """
    spec = policy.get_spec(scheduler)
    ctx = _prepare_ctx(ectx, workload, spec, seed)
    engine = _select_engine()
    if engine == "c":
        out = _csim.run(ctx)
    else:
        out = _engine_py.run(ctx)

    # serial reference: one thread on the master core, same data placement.
    if serial_reference is not None:
        serial = serial_reference
    else:
        serial = serial_time(ectx.topo, workload, ectx.thread_cores[0],
                             ctx["root_data_nodes"], ectx.params)
    return _finish_result(ctx, out, serial, engine)


def simulate(topo: Topology,
             thread_cores: Sequence[int],
             workload: Workload,
             scheduler: "str | SchedulerSpec",
             params: SimParams | None = None,
             seed: int = 0,
             root_data_nodes: int | Sequence[int] | None = None,
             runtime_data_node: int | None = None,
             migration_rate: float = 0.0,
             serial_reference: float | None = None) -> SimResult:
    """Run ``workload`` on ``len(thread_cores)`` threads; return metrics.

    Legacy positional form — a thin shim that wraps the raw arguments
    into an explicit :class:`ExecContext` and delegates to
    :func:`run_context`. New code should prefer the
    :class:`~.machine.Machine` facade, which compiles and caches
    declarative contexts (``binding="paper"``, ``placement="spill:2"``).

    Args:
      thread_cores: core id per thread; thread 0 is the master (its node
        receives the root arrays under first-touch unless overridden).
      scheduler: a registered scheduler name (see ``SCHEDULERS``) or a
        :class:`SchedulerSpec` directly.
      root_data_nodes: node(s) holding the benchmark's big arrays. Large
        inputs spill over several nodes (Linux first-touch falls back to
        nearby nodes when one fills — paper §V.B); pages are interleaved
        over the spill set, so the access distance is the mean over it.
        Default: the master thread's node (no spill).
      runtime_data_node: baseline Nanos first-touches *runtime* structures
        (task pools, descriptors) on the initializing master's node — pass
        that node to model it. ``None`` models the paper's modification:
        each thread's runtime data lives on its own node (paper §IV end).
      migration_rate: probability per task that the OS migrates the
        executing thread to another core (baseline Nanos does not pin
        threads; the paper's extension binds them). A migration pays a
        cache-refill cost and lands the depth-first chain on a new node.
      serial_reference: serial time for the speedup denominator. Default:
        :func:`serial_time` on the master core with the same data nodes.
        Pass one common value when comparing variants like the paper does.
    """
    ectx = ExecContext.from_raw(topo, params or SimParams(), thread_cores,
                                root_data_nodes, runtime_data_node,
                                migration_rate)
    return run_context(ectx, workload, scheduler, seed, serial_reference)
