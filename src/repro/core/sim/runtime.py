"""Discrete-event simulator of the NANOS task runtime on a NUMA machine.

This is the *paper-faithful* reproduction layer: OpenMP-style tasking with
per-thread LIFO task pools, the three stock Nanos schedulers the paper
benchmarks against (breadth-first, Cilk-based, work-first), and the two
NUMA-aware schedulers the paper contributes (DFWSPT, DFWSRPT), running on
an explicit hop-distance topology with a first-touch memory model.

Machine/cost model (constants in :class:`SimParams`):

* executing a task on core ``c`` costs::

      work * (1 + mem_intensity * hop_lambda *
                 (f_root * d(c, root_data_node) + f_parent * d(c, parent_exec_node)))

  ``root_data_node`` is where the benchmark's big arrays were allocated —
  the node of the *master thread's* core under Linux first-touch (paper
  §V.B); ``parent_exec_node`` is where the task's parent ran (temporaries
  + hot caches), so depth-first execution on the same core is free of the
  second term, exactly the locality the paper exploits.

* the breadth-first scheduler's single shared queue is a serialized
  resource (a lock): every push/pop waits for the previous holder. With
  millions of tiny tasks this serialization collapses scalability — the
  paper's FFT observation (speedup 4.43x@6 cores → 2.39x@16).

* a steal probe on a victim at ``d`` hops costs
  ``steal_time * (1 + hop_lambda_steal * d)`` — remote queue metadata
  lives in the victim's node memory.

The simulator is deterministic given (workload, params, seed).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional, Sequence

import numpy as np

from ..topology import Topology
from ..stealing import victim_order

__all__ = [
    "TaskSpec", "Workload", "SimParams", "SimResult", "simulate",
    "serial_time", "SCHEDULERS",
]


def serial_time(topo: "Topology", workload: "Workload", core: int,
                root_data_nodes, params: "SimParams | None" = None) -> float:
    """Single-thread execution time on ``core`` under the NUMA cost model.

    Depth-first on one core ⇒ parent data always local (d_parent = 0);
    only the root-array distance (incl. spill interleave) is paid.
    """
    p = params or SimParams()
    if root_data_nodes is None:
        root_data_nodes = [int(topo.core_node[core])]
    elif isinstance(root_data_nodes, (int, np.integer)):
        root_data_nodes = [int(root_data_nodes)]
    d_root = float(topo.node_distance[:, list(root_data_nodes)]
                   .mean(axis=1)[topo.core_node[core]])
    total = 0.0
    stack = [workload.root]
    while stack:
        s = stack.pop()
        w = s.work_pre + s.work_post
        total += w * (1.0 + workload.mem_intensity * p.hop_lambda
                      * s.f_root * d_root)
        stack.extend(s.children)
        stack.extend(s.post_children)
    return total

SCHEDULERS = ("bf", "cilk", "wf", "dfwspt", "dfwsrpt")


@dataclasses.dataclass
class TaskSpec:
    """A node of the benchmark's task tree.

    work_pre:  compute units before spawning children.
    work_post: compute units of the join continuation (0 = no taskwait).
    f_root:    fraction of this task's memory traffic hitting the root
               arrays (allocated by master at startup, first-touch).
    f_parent:  fraction hitting the parent's temporaries / caches.
    children:  sub-tasks spawned after work_pre.
    """
    work_pre: float
    work_post: float = 0.0
    f_root: float = 0.0
    f_parent: float = 0.0
    children: list["TaskSpec"] = dataclasses.field(default_factory=list)
    # spawned when all ``children`` complete (BOTS-style parallel combine
    # wave after a taskwait); ``work_post`` runs after *these* complete.
    post_children: list["TaskSpec"] = dataclasses.field(default_factory=list)

    def count(self) -> int:
        stack, n = [self], 0
        while stack:
            t = stack.pop()
            n += 1
            stack.extend(t.children)
            stack.extend(t.post_children)
        return n

    def total_work(self) -> float:
        stack, w = [self], 0.0
        while stack:
            t = stack.pop()
            w += t.work_pre + t.work_post
            stack.extend(t.children)
            stack.extend(t.post_children)
        return w


@dataclasses.dataclass
class Workload:
    name: str
    root: TaskSpec
    mem_intensity: float  # µ — how memory-bound the benchmark is (0..~1)


@dataclasses.dataclass
class SimParams:
    hop_lambda: float = 0.4         # NUMA factor slope per hop (exec)
    hop_lambda_steal: float = 2.0   # per-hop slope for steal probes
    lock_time: float = 0.25         # serialized shared-queue op cost
    deque_lock_time: float = 0.4    # victim-deque serialized op cost
    steal_time: float = 1.5         # base steal probe cost
    spawn_time: float = 0.02        # per-child task-creation overhead
    wake_latency: float = 0.05      # parked thread wake-up latency
    qop_time: float = 0.05          # local task-pool push/pop cost
    cache_refill: float = 4.0       # work units lost per thread migration


@dataclasses.dataclass
class SimResult:
    makespan: float
    serial_time: float
    speedup: float
    tasks: int
    steals: int
    failed_probes: int
    remote_work_fraction: float  # share of exec time that was NUMA penalty
    queue_wait: float            # total time spent waiting on the bf lock


# ----------------------------------------------------------------------
# Internal runtime records
# ----------------------------------------------------------------------

class _Run:
    """A live task instance."""
    __slots__ = ("spec", "parent", "pending", "exec_node", "parent_node",
                 "phase")

    def __init__(self, spec: TaskSpec, parent: Optional["_Run"], parent_node: int):
        self.spec = spec
        self.parent = parent
        self.pending = 0           # children not yet fully complete
        self.exec_node = -1        # node where work_pre ran (first touch)
        self.parent_node = parent_node
        self.phase = 0             # 0 = children wave, 1 = post wave


class _Serialized:
    """A lock: serialized access, each op occupies ``op_time``."""
    __slots__ = ("free_at", "op_time", "waited")

    def __init__(self, op_time: float):
        self.free_at = 0.0
        self.op_time = op_time
        self.waited = 0.0

    def acquire(self, t: float) -> float:
        """Returns the time the op *completes*; accumulates wait time."""
        start = max(t, self.free_at)
        self.waited += start - t
        self.free_at = start + self.op_time
        return self.free_at


def simulate(topo: Topology,
             thread_cores: Sequence[int],
             workload: Workload,
             scheduler: str,
             params: SimParams | None = None,
             seed: int = 0,
             root_data_nodes: int | Sequence[int] | None = None,
             runtime_data_node: int | None = None,
             migration_rate: float = 0.0,
             serial_reference: float | None = None) -> SimResult:
    """Run ``workload`` on ``len(thread_cores)`` threads; return metrics.

    Args:
      thread_cores: core id per thread; thread 0 is the master (its node
        receives the root arrays under first-touch unless overridden).
      scheduler: one of ``SCHEDULERS``.
      root_data_nodes: node(s) holding the benchmark's big arrays. Large
        inputs spill over several nodes (Linux first-touch falls back to
        nearby nodes when one fills — paper §V.B); pages are interleaved
        over the spill set, so the access distance is the mean over it.
        Default: the master thread's node (no spill).
      runtime_data_node: baseline Nanos first-touches *runtime* structures
        (task pools, descriptors) on the initializing master's node — pass
        that node to model it. ``None`` models the paper's modification:
        each thread's runtime data lives on its own node (paper §IV end).
      migration_rate: probability per task that the OS migrates the
        executing thread to another core (baseline Nanos does not pin
        threads; the paper's extension binds them). A migration pays a
        cache-refill cost and lands the depth-first chain on a new node.
      serial_reference: serial time for the speedup denominator. Default:
        :func:`serial_time` on the master core with the same data nodes.
        Pass one common value when comparing variants like the paper does.
    """
    if scheduler not in SCHEDULERS:
        raise ValueError(f"unknown scheduler {scheduler!r}")
    p = params or SimParams()
    rng = np.random.RandomState(seed)
    T = len(thread_cores)
    dist = topo.core_distance_matrix()
    core_node = topo.core_node
    node_dist = topo.node_distance
    cores = list(thread_cores)
    if root_data_nodes is None:
        root_data_nodes = [int(core_node[cores[0]])]
    elif isinstance(root_data_nodes, (int, np.integer)):
        root_data_nodes = [int(root_data_nodes)]
    # mean hop distance from each node to the (interleaved) root pages
    root_dist = node_dist[:, list(root_data_nodes)].mean(axis=1)

    depth_first = scheduler != "bf"
    # Victim orders. DFWSPT's list is static; DFWSRPT re-randomizes ties
    # (equal-distance victims) per sweep; stock cilk/wf sweep victims in a
    # fresh random order. Distance groups are precomputed once.
    pri_orders = None
    dist_groups: list[list[list[int]]] = []
    for th in range(T):
        by_d: dict[int, list[int]] = {}
        for v in range(T):
            if v != th:
                by_d.setdefault(int(dist[cores[th], cores[v]]), []).append(v)
        dist_groups.append([by_d[d] for d in sorted(by_d)])
    if scheduler == "dfwspt":
        pri_orders = [victim_order(topo, cores, t, "dfwspt", rng) for t in range(T)]
    all_others = [[v for v in range(T) if v != th] for th in range(T)]

    # --- state ---
    local: list[list[_Run]] = [[] for _ in range(T)]  # deque per thread
    shared: list[_Run] = []                            # bf FIFO
    shared_lock = _Serialized(p.lock_time)
    deque_locks = [_Serialized(p.deque_lock_time) for _ in range(T)]
    parked: set[int] = set()
    events: list[tuple[float, int, int, Optional[_Run]]] = []  # (t, seq, thread, task-to-run)
    seq = 0
    stats = dict(steals=0, failed=0, remote=0.0, total_exec=0.0)
    live_tasks = 1  # root
    makespan = 0.0

    def push_event(t: float, thread: int, task: Optional[_Run]):
        nonlocal seq
        seq += 1
        heapq.heappush(events, (t, seq, thread, task))

    def exec_cost(run: _Run, core: int, work: float) -> float:
        d_root = root_dist[core_node[core]]
        d_par = (node_dist[core_node[core], run.parent_node]
                 if run.parent_node >= 0 else 0)
        s = run.spec
        penalty = workload.mem_intensity * p.hop_lambda * (
            s.f_root * d_root + s.f_parent * d_par)
        stats["remote"] += work * penalty
        stats["total_exec"] += work * (1.0 + penalty)
        return work * (1.0 + penalty)

    def qop(thread: int) -> float:
        """Local task-pool op cost; remote if runtime data is centralized
        (baseline Nanos first-touch — the paper's §IV-end fix removes it)."""
        if runtime_data_node is None:
            return p.qop_time
        d = node_dist[core_node[cores[thread]], runtime_data_node]
        return p.qop_time * (1.0 + p.hop_lambda_steal * d)

    def deque_home_dist(thief: int, victim: int) -> float:
        """Hop distance from thief to the victim's pool metadata."""
        if runtime_data_node is None:
            return float(dist[cores[thief], cores[victim]])
        return float(node_dist[core_node[cores[thief]], runtime_data_node])

    def enqueue(run: _Run, thread: int, t: float) -> float:
        """Push a ready task; wake parked threads. Returns time after op."""
        if depth_first:
            t += qop(thread)
            local[thread].append(run)  # front == end of list (LIFO pop)
        else:
            t = shared_lock.acquire(t)
            shared.append(run)
        wake(t)
        return t

    def wake(t: float):
        # wake-one (Nanos-style): a single push readies a single sleeper.
        if parked:
            th = parked.pop()
            push_event(t + p.wake_latency, th, None)

    def try_acquire(thread: int, t: float) -> tuple[Optional[_Run], float]:
        """Scheduler-policy task acquisition. May advance time."""
        if depth_first:
            if local[thread]:
                return local[thread].pop(), t + qop(thread)
            # steal sweep
            if scheduler in ("cilk", "wf"):
                order = list(all_others[thread])
                rng.shuffle(order)
            elif scheduler == "dfwspt":
                order = pri_orders[thread]
            else:  # dfwsrpt: re-randomize equal-distance ties each sweep
                order = []
                for group in dist_groups[thread]:
                    g = list(group)
                    rng.shuffle(g)
                    order.extend(g)
            for v in order:
                t += p.steal_time * (1.0 + p.hop_lambda_steal
                                     * deque_home_dist(thread, v))
                if local[v]:
                    t = deque_locks[v].acquire(t)
                    if local[v]:
                        stats["steals"] += 1
                        return local[v].pop(0), t  # steal from the back
                stats["failed"] += 1
            return None, t
        # breadth-first: single shared FIFO behind one lock.
        # Peek without the lock first (cheap read) — contention comes from
        # genuine concurrent pops, not from idle polling.
        if not shared:
            return None, t
        t = shared_lock.acquire(t)
        if shared:
            return shared.pop(0), t
        return None, t

    def complete_subtree(run: _Run, thread: int, t: float) -> float:
        """Propagate completion: spawn post waves / run join continuations."""
        nonlocal live_tasks
        node = run
        while True:
            parent = node.parent
            if parent is None:
                return t
            parent.pending -= 1
            if parent.pending > 0:
                return t
            if parent.phase == 0 and parent.spec.post_children:
                # taskwait passed → spawn the parallel combine wave on the
                # thread that completed the last child (depth-first: it
                # has the hottest caches for the join data).
                parent.phase = 1
                kids = parent.spec.post_children
                parent.pending = len(kids)
                live_tasks += len(kids)
                t += p.spawn_time * len(kids)
                for k in kids[::-1]:
                    t = enqueue(_Run(k, parent, parent.exec_node), thread, t)
                return t
            # all waves done → run parent's continuation (work_post)
            if parent.spec.work_post > 0.0:
                cont = _Run(parent.spec, None, parent.exec_node)
                # continuation resumes with parent's own locality profile;
                # completion then propagates to the grandparent.
                cont_cost = exec_cost(cont, cores[thread], parent.spec.work_post)
                t += cont_cost
            node = parent

    def run_task(run: _Run, thread: int, t: float):
        nonlocal live_tasks, makespan
        if migration_rate > 0.0 and rng.random_sample() < migration_rate:
            # unbound baseline: OS moves the thread; caches refill cold.
            cores[thread] = int(rng.randint(topo.num_cores))
            t += p.cache_refill
        core = cores[thread]
        run.exec_node = int(core_node[core])  # first touch of its temporaries
        t += exec_cost(run, core, run.spec.work_pre)
        kids = run.spec.children
        if kids:
            run.pending = len(kids)
            live_tasks += len(kids)
            runs = [_Run(k, run, run.exec_node) for k in kids]
            if scheduler == "wf" or scheduler in ("dfwspt", "dfwsrpt"):
                # work-first: dive into the first child immediately,
                # queue the rest (newest in front).
                t += p.spawn_time * len(kids)
                for r in runs[1:][::-1]:
                    t = enqueue(r, thread, t)
                push_event(t, thread, runs[0])
                return
            t += p.spawn_time * len(kids)
            for r in runs[::-1] if depth_first else runs:
                t = enqueue(r, thread, t)
            # cilk-based: continue by popping own deque front (the first
            # child) — one queue round-trip more than work-first.
            push_event(t, thread, None)
            return
        # leaf (or no children): join propagation
        live_tasks -= 1
        t = complete_subtree(run, thread, t)
        makespan = max(makespan, t)
        push_event(t, thread, None)

    # ignite: master (thread 0) starts the root
    root_run = _Run(workload.root, None, int(root_data_nodes[0]))
    push_event(0.0, 0, root_run)
    for th in range(1, T):
        push_event(0.0, th, None)

    while events:
        t, _, thread, task = heapq.heappop(events)
        if task is not None:
            run_task(task, thread, t)
            continue
        got, t2 = try_acquire(thread, t)
        if got is not None:
            run_task(got, thread, t2)
        elif live_tasks > 0:
            parked.add(thread)  # woken by the next enqueue
        # else: drain — nothing left anywhere.

    # serial reference: one thread on the master core, same data placement.
    if serial_reference is not None:
        serial = serial_reference
    else:
        serial = serial_time(topo, workload, cores[0], root_data_nodes, p)
    rf = stats["remote"] / max(stats["total_exec"], 1e-12)
    return SimResult(
        makespan=makespan,
        serial_time=serial,
        speedup=serial / makespan if makespan > 0 else float("nan"),
        tasks=workload.root.count(),
        steals=stats["steals"],
        failed_probes=stats["failed"],
        remote_work_fraction=rf,
        queue_wait=shared_lock.waited,
    )
