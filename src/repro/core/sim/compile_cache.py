"""Persistent, content-addressed compile cache for the simulator.

The runtime precomputes a lot before the first event fires: paper-scale
:class:`~.table.TaskTable` builds take 0.2–1.6 s, the serial-reference
walk ~0.5 s, context/victim-plan lowering a few ms, and the first
process on a machine pays an on-demand ``cc`` build of ``_csim.so``.
All of it is *pure* — a function of content that can be fingerprinted —
so this module persists every compile product on disk and loads it back
zero-copy, taking a cold process from seconds to milliseconds:

* **task tables** — every array of a compiled table saved as an
  ``.npy`` blob, keyed by builder identity (workload name, scale, and a
  hash of the builder sources), loaded back via
  ``np.load(mmap_mode="r")`` so a paper-scale table opens without
  reading (or copying) its tens of MB; the engines treat table arrays
  as read-only, so the memory-mapped pages are shared across processes.
* **serial references** — the scalar from :func:`~.runtime.serial_time`
  keyed by (table fingerprint, topology fingerprint, data nodes, µ, λ):
  the full serial walk runs once per machine, ever. JSON round-trips
  Python floats exactly (repr is shortest-round-trip), so replayed
  values are bit-identical.
* **context lowerings** — the paper-priority thread binding and the
  first-touch spill walk, keyed by (topology fingerprint, spec, seed).
* **victim plans** — the compiled sweep programs of
  :func:`~.policy.compile_victim_plan`, keyed by (topology fingerprint,
  victim policy, core binding).
* **the C kernel** — ``_csim.py`` builds its shared object under this
  cache root, keyed by (source hash, compiler version, flags), so only
  the first process on a machine ever invokes the compiler.

Location & control
------------------

The root defaults to ``$XDG_CACHE_HOME/repro-sim`` (usually
``~/.cache/repro-sim``); override it with ``REPRO_SIM_CACHE=/path``,
disable caching entirely with ``REPRO_SIM_CACHE=0`` (every consult is
then a no-op and the C kernel builds into a per-process temp dir).
Clearing the cache is just ``rm -rf`` — every artifact is rebuilt on
demand.

Durability & integrity
----------------------

Writes are atomic: array artifacts are staged into a ``*.tmp-<pid>``
sibling directory and ``os.rename``\\ d into place, scalar artifacts go
through ``mkstemp`` + ``os.replace``. Two processes racing a write both
succeed (content under a key is identical by construction — last
rename wins with equivalent bytes). Every artifact carries a manifest
with a checksum and per-array dtype/shape/byte-size records; a torn,
corrupted, or version-mismatched artifact is detected at load, warned
about once, deleted best-effort, and the caller rebuilds — corruption
can cost time, never correctness. Array *data* checksums are verified
eagerly for small artifacts; multi-MB blobs are validated structurally
(header + exact byte size) so a hit stays O(ms) — export
``REPRO_SIM_CACHE_VERIFY=1`` to force full data verification.

Layout::

    <root>/
      csim/csim_<tag>.so          # compiled kernels (see _csim.py)
      tables/<key>/manifest.json + <array>.npy
      serial/<key>.json
      contexts/<key>.json
      plans/<key>.json
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import warnings

import numpy as np

__all__ = ["CompileCache", "get_cache", "reset_cache", "cache_root",
           "source_fingerprint", "digest_key"]

ENV_VAR = "REPRO_SIM_CACHE"
FORMAT = "repro-sim-compile-cache"
VERSION = 1

# artifacts at or below this byte size get their data checksums verified
# on every load; larger ones are validated structurally unless
# REPRO_SIM_CACHE_VERIFY=1 (full verification would read — and so page
# in — every mmap'd byte, defeating the zero-copy load).
_VERIFY_LIMIT = 1 << 20


def cache_root() -> "str | None":
    """Resolve the cache root directory (``None`` = caching disabled)."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        env = env.strip()
        if env in ("", "0", "off", "none"):
            return None
        return env
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(base, "repro-sim")


# (env value at resolution time, cache instance or None); re-resolved
# whenever REPRO_SIM_CACHE changes, mirroring the engine-choice cache.
_cache_state: "tuple[str | None, CompileCache | None] | None" = None


def get_cache() -> "CompileCache | None":
    """The process-wide cache handle (``None`` when disabled).

    One handle is shared by every consumer — ``bots.make``, the serial
    reference, context/plan lowering, grid sweeps — so hit/miss
    statistics aggregate across a whole run.
    """
    global _cache_state
    env = os.environ.get(ENV_VAR)
    state = _cache_state
    if state is not None and state[0] == env:
        return state[1]
    root = cache_root()
    cache = CompileCache(root) if root is not None else None
    _cache_state = (env, cache)
    return cache


def reset_cache() -> None:
    """Drop the cached handle (tests / after changing ``REPRO_SIM_CACHE``)."""
    global _cache_state
    _cache_state = None


def digest_key(*material) -> str:
    """Stable 32-hex digest of arbitrary repr-able key material."""
    return hashlib.blake2b(repr(material).encode(),
                           digest_size=16).hexdigest()


_source_fps: dict = {}


def source_fingerprint(*modules) -> str:
    """Content hash of the given modules' source files (cached).

    Used as the *builder identity* component of table keys: editing a
    workload builder (or the table layout it compiles into) changes the
    hash, so stale artifacts miss instead of shadowing the new code.
    """
    key = tuple(m.__name__ for m in modules)
    fp = _source_fps.get(key)
    if fp is None:
        h = hashlib.blake2b(digest_size=16)
        for m in modules:
            with open(m.__file__, "rb") as f:
                h.update(f.read())
        fp = h.hexdigest()
        _source_fps[key] = fp
    return fp


def _checksum(payload) -> str:
    return hashlib.blake2b(
        json.dumps(payload, sort_keys=True, separators=(",", ":")).encode(),
        digest_size=16).hexdigest()


class CompileCache:
    """On-disk artifact cache rooted at ``root`` (see module docstring).

    All ``get_*`` methods return ``None`` on a miss *or* on a corrupt /
    version-mismatched artifact (after a one-time warning naming it);
    all ``put_*`` methods are atomic and silently tolerate a concurrent
    writer. ``stats()`` reports per-category hits/misses/corruptions.
    """

    def __init__(self, root: str):
        self.root = os.fspath(root)
        self.hits: dict = {}
        self.misses: dict = {}
        self.corrupt: dict = {}
        self._warned: set = set()
        self._verify_all = bool(os.environ.get("REPRO_SIM_CACHE_VERIFY"))

    def __repr__(self) -> str:
        return (f"CompileCache({self.root!r}: hits={self.hits}, "
                f"misses={self.misses})")

    def stats(self) -> dict:
        return dict(hits=dict(self.hits), misses=dict(self.misses),
                    corrupt=dict(self.corrupt))

    def hit_count(self, category: "str | None" = None) -> int:
        if category is not None:
            return self.hits.get(category, 0)
        return sum(self.hits.values())

    # -- bookkeeping ----------------------------------------------------
    def _tally(self, book: dict, category: str) -> None:
        book[category] = book.get(category, 0) + 1

    def _discard(self, category: str, key: str, path: str,
                 why: str) -> None:
        """Corrupt artifact: warn once, tally, remove best-effort."""
        self._tally(self.corrupt, category)
        self._tally(self.misses, category)
        if path not in self._warned:
            self._warned.add(path)
            warnings.warn(
                f"compile cache: discarding {category}/{key} ({why}); "
                "rebuilding from scratch", RuntimeWarning, stacklevel=4)
        try:
            if os.path.isdir(path):
                shutil.rmtree(path, ignore_errors=True)
            elif os.path.exists(path):
                os.unlink(path)
        except OSError:
            pass

    def _dir(self, category: str) -> str:
        return os.path.join(self.root, category)

    # -- scalar (JSON) artifacts ----------------------------------------
    def _json_path(self, category: str, key: str) -> str:
        return os.path.join(self.root, category, key + ".json")

    def put_json(self, category: str, key: str, payload) -> None:
        """Atomically store a small JSON-able payload under a key."""
        d = self._dir(category)
        os.makedirs(d, exist_ok=True)
        doc = {"format": FORMAT, "version": VERSION, "key": key,
               "payload": payload, "checksum": _checksum(payload)}
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(doc, f, separators=(",", ":"))
            os.replace(tmp, self._json_path(category, key))
        except OSError:
            # cache dir vanished / quota / read-only fs: caching is
            # best-effort, never a failure of the caller
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def get_json(self, category: str, key: str):
        """Load a JSON payload; ``None`` on miss/corruption."""
        path = self._json_path(category, key)
        try:
            with open(path, "r", encoding="utf-8") as f:
                doc = json.load(f)
        except FileNotFoundError:
            self._tally(self.misses, category)
            return None
        except (ValueError, OSError):
            self._discard(category, key, path, "unparseable JSON")
            return None
        if doc.get("format") != FORMAT or doc.get("version") != VERSION:
            self._discard(category, key, path, "version mismatch")
            return None
        payload = doc.get("payload")
        if _checksum(payload) != doc.get("checksum"):
            self._discard(category, key, path, "checksum mismatch")
            return None
        self._tally(self.hits, category)
        return payload

    # -- array artifacts (directory of .npy + manifest) -----------------
    def _array_dir(self, category: str, key: str) -> str:
        return os.path.join(self.root, category, key)

    def put_arrays(self, category: str, key: str,
                   arrays: "dict[str, np.ndarray]", meta: dict) -> None:
        """Atomically store named arrays + metadata under a key.

        Stages everything into a ``<key>.tmp-<pid>`` sibling and renames
        the directory into place; a concurrent writer's rename losing
        the race is fine (equal keys hold equal content).
        """
        final = self._array_dir(category, key)
        if os.path.isdir(final):
            return                      # first write wins
        parent = self._dir(category)
        try:
            os.makedirs(parent, exist_ok=True)
            stage = tempfile.mkdtemp(prefix=key + ".tmp-", dir=parent)
        except OSError:
            return
        try:
            manifest_arrays = {}
            for name, arr in arrays.items():
                arr = np.ascontiguousarray(arr)
                np.save(os.path.join(stage, name + ".npy"), arr)
                manifest_arrays[name] = {
                    "file": name + ".npy",
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "nbytes": int(arr.nbytes),
                    "blake2b": hashlib.blake2b(
                        arr.tobytes(), digest_size=16).hexdigest(),
                }
            payload = {"arrays": manifest_arrays, "meta": meta}
            doc = {"format": FORMAT, "version": VERSION, "key": key,
                   "payload": payload, "checksum": _checksum(payload)}
            with open(os.path.join(stage, "manifest.json"), "w",
                      encoding="utf-8") as f:
                json.dump(doc, f, separators=(",", ":"))
            try:
                os.rename(stage, final)
            except OSError:
                shutil.rmtree(stage, ignore_errors=True)  # lost the race
        except OSError:
            shutil.rmtree(stage, ignore_errors=True)

    def get_arrays(self, category: str, key: str,
                   mmap: bool = True):
        """Load ``(arrays, meta)`` back; ``None`` on miss/corruption.

        Arrays come back as read-only memory maps (``mmap=True``) —
        opening is O(header), data pages fault in on demand — or plain
        in-memory copies. Structural validation (manifest checksum,
        dtype/shape/byte size per array) always runs; data checksums
        run for small artifacts or under ``REPRO_SIM_CACHE_VERIFY=1``.
        """
        adir = self._array_dir(category, key)
        mpath = os.path.join(adir, "manifest.json")
        try:
            with open(mpath, "r", encoding="utf-8") as f:
                doc = json.load(f)
        except FileNotFoundError:
            self._tally(self.misses, category)
            return None
        except (ValueError, OSError):
            self._discard(category, key, adir, "unparseable manifest")
            return None
        if doc.get("format") != FORMAT or doc.get("version") != VERSION:
            self._discard(category, key, adir, "version mismatch")
            return None
        payload = doc.get("payload")
        if not isinstance(payload, dict) or \
                _checksum(payload) != doc.get("checksum"):
            self._discard(category, key, adir, "manifest checksum mismatch")
            return None
        arrays = {}
        total = sum(rec["nbytes"] for rec in payload["arrays"].values())
        verify = self._verify_all or total <= _VERIFY_LIMIT
        for name, rec in payload["arrays"].items():
            path = os.path.join(adir, rec["file"])
            try:
                arr = np.load(path, mmap_mode="r" if mmap else None,
                              allow_pickle=False)
            except (ValueError, OSError):
                self._discard(category, key, adir,
                              f"torn/unreadable array {rec['file']!r}")
                return None
            if (str(arr.dtype) != rec["dtype"]
                    or list(arr.shape) != rec["shape"]
                    or int(arr.nbytes) != rec["nbytes"]
                    or not arr.flags["C_CONTIGUOUS"]):
                self._discard(category, key, adir,
                              f"array {rec['file']!r} does not match its "
                              "manifest record")
                return None
            if verify and hashlib.blake2b(
                    arr.tobytes(), digest_size=16).hexdigest() \
                    != rec["blake2b"]:
                self._discard(category, key, adir,
                              f"array {rec['file']!r} data checksum "
                              "mismatch")
                return None
            arrays[name] = arr
        self._tally(self.hits, category)
        return arrays, payload["meta"]

    # ------------------------------------------------------------------
    # Typed helpers: task tables / workloads
    # ------------------------------------------------------------------
    def get_workload(self, key: str):
        """Load a cached :class:`~.runtime.Workload` (mmap-backed table)."""
        hit = self.get_arrays("tables", key)
        if hit is None:
            return None
        arrays, meta = hit
        from .runtime import Workload
        from .table import TaskTable
        try:
            tbl = TaskTable.restore(arrays, fingerprint=meta["fingerprint"])
        except (KeyError, ValueError):
            self._discard("tables", key, self._array_dir("tables", key),
                          "incomplete table artifact")
            return None
        return Workload(meta["name"], None, float(meta["mem_intensity"]),
                        table=tbl)

    def put_workload(self, key: str, workload) -> None:
        """Store a workload's compiled table (+ identity metadata)."""
        from .runtime import ensure_table
        tbl = ensure_table(workload)
        meta = dict(name=workload.name,
                    mem_intensity=float(workload.mem_intensity),
                    tasks=int(tbl.n),
                    fingerprint=tbl.fingerprint())
        self.put_arrays("tables", key, tbl.saved_arrays(), meta)

    # ------------------------------------------------------------------
    # Typed helpers: serial references
    # ------------------------------------------------------------------
    def get_serial(self, key: str) -> "float | None":
        payload = self.get_json("serial", key)
        if payload is None:
            return None
        try:
            return float(payload["serial"])
        except (KeyError, TypeError, ValueError):
            self._discard("serial", key, self._json_path("serial", key),
                          "malformed serial record")
            return None

    def put_serial(self, key: str, value: float) -> None:
        self.put_json("serial", key, {"serial": float(value)})

    # ------------------------------------------------------------------
    # Typed helpers: context lowerings (int tuples)
    # ------------------------------------------------------------------
    def get_int_tuple(self, category: str, key: str) -> "tuple | None":
        payload = self.get_json(category, key)
        if payload is None:
            return None
        try:
            return tuple(int(v) for v in payload["values"])
        except (KeyError, TypeError, ValueError):
            self._discard(category, key, self._json_path(category, key),
                          "malformed tuple record")
            return None

    def put_int_tuple(self, category: str, key: str, values) -> None:
        self.put_json(category, key, {"values": [int(v) for v in values]})

    # ------------------------------------------------------------------
    # Typed helpers: victim plans
    # (per-thread group/unit/victim nestings — [th][group][unit][victim])
    # ------------------------------------------------------------------
    def get_victim_groups(self, key: str):
        payload = self.get_json("plans", key)
        if payload is None:
            return None
        try:
            return [[[[int(v) for v in unit] for unit in group]
                     for group in per_thread]
                    for per_thread in payload["groups"]]
        except (KeyError, TypeError, ValueError):
            self._discard("plans", key, self._json_path("plans", key),
                          "malformed victim-plan record")
            return None

    def put_victim_groups(self, key: str, groups) -> None:
        self.put_json("plans", key, {"groups": groups})
