"""Declarative fault models: perturbed-execution simulation.

Every paper figure assumes perfectly healthy cores; real multi-socket
machines have stragglers, preempted threads, and failed cores — and it
is exactly under such perturbation that load-balancing strategies
separate (Wang et al. 2025). This module makes faults a *declarative
policy* like schedulers (``policy.py``) and bindings/placements
(``context.py``):

  * :class:`FaultSpec` — one fault model:
      ``"straggler:S"``      one bound core (drawn from the fault RNG)
                             executes all work ``(1+S)×`` slower;
      ``"straggler:S@a,b"``  explicit core ids instead of a draw;
      ``"preempt:N"``        per thread, ``Poisson(N)`` offline windows
                             with starts ~ ``U[0, span)`` and durations
                             ~ ``Exp(duration)`` — the thread goes
                             offline for the window, its in-hand task is
                             reclaimed (re-queued, stealable) and it
                             resumes at the window end;
      ``"preempt:N@D"``      mean window duration ``D``;
      ``"fail:K"``           ``K`` distinct threads (drawn) fail
                             *permanently* at times ~ ``U[0, span)``;
                             their queued tasks are reclaimed and
                             re-stolen, aborted work re-executes
                             elsewhere — deterministic re-execution;
      ``"fail:K@T"``         the drawn threads all fail at fixed time T.

  * :class:`FaultPlan` — the compiled form both engines consume through
    one lowered representation, exactly as victim plans do: a per-core
    ``speed`` multiplier vector plus per-thread sorted, merged
    ``(start, end)`` offline windows in flat CSR arrays
    (``win_off``/``win_start``/``win_end``; a permanent failure is a
    window ending at ``+inf``).

All randomness is consumed at *compile* time from a dedicated fault RNG
stream seeded from ``(FAULT_STREAM, seed)`` — the engines' own
``RandomState(seed)`` task-execution draw order is untouched, which is
how every fault-free configuration stays bit-exact against the golden
fixtures. Plans are cached on the topology per (specs, binding, seed)
like victim plans, so sweeps share them across cells.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..topology import Topology, lazy_cache

__all__ = [
    "FaultSpec", "FaultPlan", "FAULTS",
    "register_fault", "get_fault", "get_faults", "compile_fault_plan",
    "FAULT_KINDS",
]

FAULT_KINDS = ("straggler", "preempt", "fail")

# Stream-id prefix for the dedicated fault RNG: RandomState([FAULT_STREAM,
# seed]) never collides with the engines' RandomState(seed) draw sequence.
FAULT_STREAM = 0xFA17


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault model (see module docstring).

    Fields by kind:
      straggler: ``severity`` S (cost multiplier ``1+S``), ``cores``
        (explicit core ids, or None → one core drawn from the bound set).
      preempt:   ``count`` (expected windows per thread, Poisson),
        ``duration`` (mean offline interval, exponential), ``span``
        (window-start horizon, uniform).
      fail:      ``count`` (threads failed, drawn without replacement),
        ``at`` (fixed failure time, or None → drawn ~ U[0, span)).
    """
    name: str
    kind: str = "straggler"
    severity: float = 0.5
    cores: Optional[tuple] = None
    count: float = 1.0
    duration: float = 20.0
    span: float = 200.0
    at: Optional[float] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind={self.kind!r}: expected one of {FAULT_KINDS}")
        if self.severity < 0.0:
            raise ValueError(f"fault {self.name!r}: severity "
                             f"{self.severity} < 0")
        if self.count < 0:
            raise ValueError(f"fault {self.name!r}: count {self.count} < 0")
        if self.duration <= 0.0:
            raise ValueError(f"fault {self.name!r}: duration "
                             f"{self.duration} <= 0")
        if self.span <= 0.0:
            raise ValueError(f"fault {self.name!r}: span {self.span} <= 0")
        if self.at is not None and self.at < 0.0:
            raise ValueError(f"fault {self.name!r}: at {self.at} < 0")
        if self.cores is not None:
            if self.kind != "straggler":
                raise ValueError(f"fault kind={self.kind!r} takes no "
                                 "explicit core list")
            if not self.cores:
                raise ValueError("explicit straggler needs a non-empty "
                                 "core tuple")
            object.__setattr__(self, "cores",
                               tuple(int(c) for c in self.cores))
        if self.kind == "fail" and self.count != int(self.count):
            raise ValueError(f"fault {self.name!r}: fail count must be "
                             f"an integer, got {self.count}")

    def validate(self, topo: Topology, num_threads: int) -> None:
        """Eager per-context validation (bad cells fail at compile time,
        naming the spec, not mid-batch inside an engine)."""
        if self.kind == "straggler" and self.cores is not None:
            bad = [c for c in self.cores if not 0 <= c < topo.num_cores]
            if bad:
                raise ValueError(f"fault {self.name!r}: cores {bad} outside "
                                 f"topology ({topo.num_cores} cores)")
        if self.kind == "fail":
            if int(self.count) >= num_threads:
                raise ValueError(
                    f"fault {self.name!r}: failing {int(self.count)} of "
                    f"{num_threads} threads would leave no survivor")


class FaultPlan:
    """Compiled fault plan — the flat arrays both engines consume.

    ``speed[c]``: execution-cost multiplier of topology core ``c``
    (1.0 = healthy; migration can land a thread on a straggler core).
    Thread ``th``'s offline windows occupy
    ``win_start/win_end[win_off[th]:win_off[th+1]]`` — sorted by start,
    non-overlapping (merged at compile), ``end = inf`` for a permanent
    failure.
    """

    __slots__ = ("speed", "win_off", "win_start", "win_end", "n_windows")

    def __init__(self, speed, win_off, win_start, win_end):
        self.speed = np.ascontiguousarray(speed, dtype=np.float64)
        self.win_off = np.ascontiguousarray(win_off, dtype=np.int64)
        self.win_start = np.ascontiguousarray(win_start, dtype=np.float64)
        self.win_end = np.ascontiguousarray(win_end, dtype=np.float64)
        self.n_windows = int(self.win_start.shape[0])

    @property
    def is_neutral(self) -> bool:
        """True when the plan perturbs nothing (all speeds 1, no
        windows) — the engines' fault hook still runs, bit-exactly."""
        return self.n_windows == 0 and bool((self.speed == 1.0).all())


# ----------------------------------------------------------------------
# Registry + string forms
# ----------------------------------------------------------------------

FAULTS: dict = {}


def register_fault(spec: FaultSpec, *, replace: bool = False) -> FaultSpec:
    """Register ``spec`` under ``spec.name``; returns it for chaining."""
    if not replace and spec.name in FAULTS:
        raise ValueError(f"fault {spec.name!r} already registered "
                         "(pass replace=True to override)")
    FAULTS[spec.name] = spec
    return spec


def _num(text: str, what: str) -> float:
    try:
        return float(text)
    except ValueError:
        raise ValueError(f"malformed fault {what} {text!r}") from None


def get_fault(fault) -> FaultSpec:
    """Resolve one fault: a spec, a registered name, or a parametrized
    string (``straggler:S[@a,b]``, ``preempt:N[@D]``, ``fail:K[@T]``)."""
    if isinstance(fault, FaultSpec):
        return fault
    if not isinstance(fault, str):
        raise TypeError(f"cannot interpret {fault!r} as a fault spec")
    spec = FAULTS.get(fault)
    if spec is not None:
        return spec
    kind, sep, body = fault.partition(":")
    if not sep or kind not in FAULT_KINDS:
        raise ValueError(
            f"unknown fault {fault!r}; registered: {sorted(FAULTS)} (or "
            "'straggler:S[@a,b]', 'preempt:N[@D]', 'fail:K[@T]')")
    head, asep, tail = body.partition("@")
    if kind == "straggler":
        severity = _num(head, "severity")
        cores = None
        if asep:
            try:
                cores = tuple(int(p) for p in tail.split(",") if p != "")
            except ValueError:
                raise ValueError(
                    f"malformed fault core list {tail!r}") from None
        return FaultSpec(fault, kind="straggler", severity=severity,
                         cores=cores)
    if kind == "preempt":
        kw = dict(count=_num(head, "rate"))
        if asep:
            kw["duration"] = _num(tail, "duration")
        return FaultSpec(fault, kind="preempt", **kw)
    # kind == "fail"
    kw = dict(count=_num(head, "count"))
    if asep:
        kw["at"] = _num(tail, "time")
    return FaultSpec(fault, kind="fail", **kw)


def get_faults(faults) -> tuple:
    """Normalize a fault description into a tuple of :class:`FaultSpec`.

    Accepts ``None`` / ``()`` (no faults), one spec or string, or a
    sequence of them (composed in order into one plan).
    """
    if faults is None:
        return ()
    if isinstance(faults, (FaultSpec, str)):
        return (get_fault(faults),)
    if isinstance(faults, (list, tuple)):
        return tuple(get_fault(f) for f in faults)
    raise TypeError(f"cannot interpret {faults!r} as faults")


# ----------------------------------------------------------------------
# Compilation
# ----------------------------------------------------------------------

def _merge_windows(wins: list) -> list:
    """Sort by start and merge overlapping/touching intervals; anything
    at or after a permanent failure's start is absorbed by it."""
    if not wins:
        return []
    wins = sorted(wins)
    out = [list(wins[0])]
    for s, e in wins[1:]:
        if s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1][1] = e
        else:
            out.append([s, e])
    return out


def compile_fault_plan(specs: Sequence[FaultSpec], topo: Topology,
                       thread_cores: Sequence[int], seed: int) -> FaultPlan:
    """Compile (and cache) ``specs`` into one :class:`FaultPlan`.

    All stochastic draws (straggler core choice, window starts/durations,
    failure times/threads) happen here, from the dedicated
    ``RandomState([FAULT_STREAM, seed])`` stream — never inside an
    engine. The cache lives on the (frozen, immutable) topology, keyed
    by (specs, binding, seed): a robustness sweep reuses one plan across
    every (workload, scheduler) cell that shares a context and seed.
    """
    specs = tuple(specs)
    cores = tuple(int(c) for c in thread_cores)
    cache = lazy_cache(topo, "_fault_plan_cache")
    key = (specs, cores, seed)
    plan = cache.get(key)
    if plan is not None:
        return plan

    T = len(cores)
    for spec in specs:
        spec.validate(topo, T)
    rng = np.random.RandomState([FAULT_STREAM, seed & 0xFFFFFFFF])
    speed = np.ones(topo.num_cores, dtype=np.float64)
    wins: list[list] = [[] for _ in range(T)]
    inf = float("inf")
    for spec in specs:
        if spec.kind == "straggler":
            if spec.cores is not None:
                targets = spec.cores
            else:
                targets = (cores[int(rng.randint(T))],)
            for c in targets:
                speed[c] *= 1.0 + spec.severity
        elif spec.kind == "preempt":
            for th in range(T):
                n = int(rng.poisson(spec.count))
                if n == 0:
                    continue
                starts = rng.uniform(0.0, spec.span, n)
                durs = rng.exponential(spec.duration, n)
                for s, d in zip(starts.tolist(), durs.tolist()):
                    wins[th].append((s, s + d))
        else:  # fail
            k = int(spec.count)
            if k == 0:
                continue
            victims = rng.permutation(T)[:k]
            if spec.at is not None:
                times = [float(spec.at)] * k
            else:
                times = rng.uniform(0.0, spec.span, k).tolist()
            for th, at in zip(victims.tolist(), times):
                wins[th].append((float(at), inf))

    win_off = [0]
    win_start: list[float] = []
    win_end: list[float] = []
    dead = 0
    for th in range(T):
        merged = _merge_windows(wins[th])
        if merged and merged[-1][1] == inf:
            dead += 1
        for s, e in merged:
            win_start.append(s)
            win_end.append(e)
        win_off.append(len(win_start))
    if T and dead == T:
        raise ValueError(
            f"fault plan {tuple(s.name for s in specs)} fails all {T} "
            "threads permanently — no survivor could finish the workload")
    plan = FaultPlan(speed, win_off, win_start, win_end)
    cache[key] = plan
    return plan
