/* Event-loop body of the simulator, included twice from _csim.c:
 *
 *     #define CSIM_TRACED 0
 *     #define CSIM_NAME sim_run_notrace
 *     #include "_csim_core.h"
 *
 * and again with CSIM_TRACED 1 / CSIM_NAME sim_run_trace. The traced
 * variant records exec/steal/migration events into a trace_t (defined
 * in _csim.c before inclusion); in the untraced variant every
 * recording site is compiled out entirely, so the hot path that the
 * golden fixtures and the warm-perf gate measure is untouched by the
 * tracing subsystem. The cheap always-on locality aggregates
 * (agg_steal_hops / agg_node_tasks / agg_node_remote, caller-allocated
 * and zeroed) are updated in both variants.
 *
 * Semantics are a bit-exact transcription of _engine_py.run; see the
 * sim_run contract comment in _csim.c for the parameter layout.
 */

static int CSIM_NAME(
            const double *dpar, const int64_t *ipar,
            const double *wp, const double *wpo,
            const double *fr, const double *fp,
            const int64_t *fc, const int64_t *nc,
            const int64_t *fpw, const int64_t *npw,
            const int64_t *par,
            const int64_t *core_node, const int64_t *node_dist,
            const double *root_dist,
            int64_t *cores,
            const int64_t *vp_group_off,   /* T+1 */
            const int64_t *vp_unit_off,    /* n_groups+1 */
            const int64_t *vp_victim_off,  /* n_units+1 */
            const int64_t *vp_victims,     /* total victim slots */
            const double *fspeed,          /* num_cores (faults) */
            const int64_t *fwoff,          /* T+1 (faults) */
            const double *fwstart,         /* n_windows (faults) */
            const double *fwend,           /* n_windows (faults) */
            double *dout, int64_t *iout,
            int64_t *agg_steal_hops,       /* max_hop+1, zeroed */
            int64_t *agg_node_tasks,       /* num_nodes, zeroed */
            double *agg_node_remote,       /* num_nodes, zeroed */
            trace_t *tp)
{
    const double hop_lambda = dpar[0], hop_lambda_steal = dpar[1];
    const double lock_time = dpar[2], deque_lock_time = dpar[3];
    const double steal_time = dpar[4], spawn_time = dpar[5];
    const double wake_latency = dpar[6], qop_time = dpar[7];
    const double cache_refill = dpar[8], mem_intensity = dpar[9];
    const double migration_rate = dpar[10];
    const int64_t T = ipar[0], num_cores = ipar[1], NN = ipar[2];
    const int64_t n_tasks = ipar[3];
    const int depth_first = !ipar[4];
    const int wf_like = (int)ipar[5];
    const uint32_t seed = (uint32_t)ipar[6];
    const int64_t rdn = ipar[7];
    const int64_t rnode0 = ipar[8];
    const int has_faults = (int)ipar[9];
    int64_t max_steps = ipar[10];
    const double mu_lam = mem_intensity * hop_lambda;
    if (max_steps <= 0)
        max_steps = INT64_MAX;
#if !CSIM_TRACED
    (void)tp;
#endif

    int rc = -1;
    rk_state rng;
    rk_seed(&rng, seed);

    int64_t *pending = (int64_t *)calloc((size_t)n_tasks, sizeof(int64_t));
    int64_t *exec_node = (int64_t *)calloc((size_t)n_tasks, sizeof(int64_t));
    uint8_t *phase = (uint8_t *)calloc((size_t)n_tasks, 1);
    int64_t *order = (int64_t *)malloc((size_t)(T > 1 ? T : 1) * sizeof(int64_t));
    int64_t *uidx = (int64_t *)malloc((size_t)(T > 1 ? T : 1) * sizeof(int64_t));
    double *dl_free = (double *)calloc((size_t)T, sizeof(double));
    ring_t *local = (ring_t *)calloc((size_t)T, sizeof(ring_t));
    int64_t *wcur = (int64_t *)malloc((size_t)T * sizeof(int64_t));
    if (!pending || !exec_node || !phase || !order || !uidx || !dl_free ||
        !local || !wcur)
        goto fail1;
    if (has_faults)
        for (int64_t i = 0; i < T; i++)
            wcur[i] = fwoff[i];
    for (int64_t i = 0; i < T; i++)
        if (ring_init(&local[i], 256)) goto fail1;
    ring_t shared;
    if (ring_init(&shared, 1024)) goto fail1;
    heap_t evq;
    if (heap_init(&evq, (size_t)(2 * T + 8))) goto fail2;
    pyset_t parked;
    if (pyset_init(&parked)) goto fail3;

    double sl_free = 0.0, sl_waited = 0.0;
    double remote = 0.0, total_exec = 0.0, makespan = 0.0;
    int64_t steals = 0, failed = 0, live = 1;
    int64_t reclaimed = 0, reexec = 0, executed = 0, steps = 0, status = 0;
    double fault_lost = 0.0, last_t = 0.0;
    uint64_t seq = 0;
    fault_env_t fenv = {&evq, &parked, local, &shared, fwend,
                        wake_latency, depth_first, &seq, &reclaimed};

    /* ignition: master runs the root, workers go hunting */
    seq++; if (heap_push(&evq, 0.0, seq, 0, 0)) goto fail4;
    for (int64_t th = 1; th < T; th++) {
        seq++;
        if (heap_push(&evq, 0.0, seq, (int32_t)th, -1)) goto fail4;
    }

    while (evq.len) {
        ev_t ev = heap_pop(&evq);
        double t = ev.t;
        int64_t th = ev.th;
        int64_t task = ev.task;

        if (++steps > max_steps) {
            status = 1;
            last_t = t;
            break;
        }
        if (has_faults) {
            int64_t c = wcur[th];
            const int64_t lim = fwoff[th + 1];
            while (c < lim && fwend[c] <= t)
                c++;
            wcur[th] = c;
            if (c < lim && fwstart[c] <= t) {
                if (go_offline(&fenv, t, th, task, c)) goto fail4;
                continue;
            }
        }

        if (task < 0) {
            /* ---- acquire: local pop / steal sweep / shared FIFO ---- */
            if (depth_first) {
                ring_t *lp = &local[th];
                if (lp->len) {
                    task = ring_pop_back(lp);
                    if (rdn < 0)
                        t += qop_time;
                    else
                        t += qop_time * (1.0 + hop_lambda_steal *
                             (double)node_dist[core_node[cores[th]] * NN + rdn]);
                } else {
                    /* materialize one sweep from the compiled plan */
                    int64_t n_order = 0;
                    for (int64_t g = vp_group_off[th];
                         g < vp_group_off[th + 1]; g++) {
                        const int64_t u0 = vp_unit_off[g];
                        const int64_t u1 = vp_unit_off[g + 1];
                        const int64_t nu = u1 - u0;
                        if (nu > 1) {
                            for (int64_t k = 0; k < nu; k++)
                                uidx[k] = u0 + k;
                            rk_shuffle(&rng, uidx, nu);
                            for (int64_t k = 0; k < nu; k++)
                                for (int64_t j = vp_victim_off[uidx[k]];
                                     j < vp_victim_off[uidx[k] + 1]; j++)
                                    order[n_order++] = vp_victims[j];
                        } else {
                            for (int64_t j = vp_victim_off[u0];
                                 j < vp_victim_off[u1]; j++)
                                order[n_order++] = vp_victims[j];
                        }
                    }
                    task = -1;
                    const int64_t tn = core_node[cores[th]];
                    for (int64_t k = 0; k < n_order; k++) {
                        int64_t v = order[k];
                        double d = (rdn < 0)
                            ? (double)node_dist[tn * NN + core_node[cores[v]]]
                            : (double)node_dist[tn * NN + rdn];
                        t += steal_time * (1.0 + hop_lambda_steal * d);
                        ring_t *lv = &local[v];
                        if (lv->len) {
                            double start = t > dl_free[v] ? t : dl_free[v];
                            t = start + deque_lock_time;
                            dl_free[v] = t;
                            steals++;
                            task = ring_pop_front(lv);
                            /* hop distance thief-core -> victim-core
                             * (the stolen task's data locality,
                             * independent of the probe cost, which
                             * models queue metadata placement) */
                            {
                                const int64_t sd =
                                    node_dist[tn * NN + core_node[cores[v]]];
                                agg_steal_hops[sd]++;
#if CSIM_TRACED
                                if (trace_steal(tp, t, th, v, task, sd))
                                    goto fail4;
#endif
                            }
                            break;
                        }
                        failed++;
                    }
                    if (task < 0) {
                        if (live > 0 && pyset_add(&parked, th)) goto fail4;
                        continue;
                    }
                }
            } else {
                /* breadth-first shared FIFO behind one lock */
                if (!shared.len) {
                    if (live > 0 && pyset_add(&parked, th)) goto fail4;
                    continue;
                }
                double start = t > sl_free ? t : sl_free;
                sl_waited += start - t;
                t = start + lock_time;
                sl_free = t;
                if (!shared.len) {
                    if (live > 0 && pyset_add(&parked, th)) goto fail4;
                    continue;
                }
                task = ring_pop_front(&shared);
            }
        }

        /* ---- run `task` on thread th at time t ---- */
        if (migration_rate > 0.0 && rk_double(&rng) < migration_rate) {
#if CSIM_TRACED
            const int64_t mig_from = cores[th];
#endif
            /* randint(1) is special-cased by numpy: no draw consumed */
            cores[th] = (num_cores > 1)
                ? (int64_t)rk_interval(&rng, (uint32_t)(num_cores - 1)) : 0;
            t += cache_refill;
#if CSIM_TRACED
            if (trace_mig(tp, t, th, mig_from, cores[th])) goto fail4;
#endif
        }
        const int64_t core = cores[th];
        const int64_t n = core_node[core];
        exec_node[task] = n;
        const int64_t pr = par[task];
        const int64_t pn = pr >= 0 ? exec_node[pr] : rnode0;
        double pen = mu_lam * (fr[task] * root_dist[n] +
                               fp[task] * (double)node_dist[n * NN + pn]);
        double w = wp[task];
        double cost = w * (1.0 + pen);
        if (has_faults) {
            cost = cost * fspeed[core];
            int64_t c = wcur[th];
            const int64_t lim = fwoff[th + 1];
            /* t advanced during acquire (probes, locks): windows may
             * have closed — or opened — since the top-of-loop check. */
            while (c < lim && fwend[c] <= t)
                c++;
            wcur[th] = c;
            if (c < lim && fwstart[c] < t + cost) {
                /* preempted/killed mid-execution: partial work is lost
                 * and the task re-executes */
                double s = fwstart[c];
                if (s < t)
                    s = t;
                fault_lost += s - t;
                reexec++;
                if (go_offline(&fenv, s, th, task, c)) goto fail4;
                continue;
            }
        }
        remote += w * pen;
        total_exec += cost;
        agg_node_tasks[n]++;
        agg_node_remote[n] += w * pen;
#if CSIM_TRACED
        if (trace_exec(tp, task, th, core, n,
                       depth_first ? (int64_t)local[th].len
                                   : (int64_t)shared.len,
                       t, t + cost))
            goto fail4;
#endif
        t += cost;
        executed++;

        const int64_t nk = nc[task];
        if (nk) {
            const int64_t base = fc[task];
            pending[task] = nk;
            live += nk;
            t += spawn_time * (double)nk;
            double qc = (rdn < 0) ? qop_time
                : qop_time * (1.0 + hop_lambda_steal *
                              (double)node_dist[n * NN + rdn]);
            if (wf_like) {
                /* dive into first child; queue the rest newest-first */
                ring_t *lp = &local[th];
                for (int64_t k = base + nk - 1; k > base; k--) {
                    t += qc;
                    if (ring_push_back(lp, k)) goto fail4;
                    if (parked.used) {
                        seq++;
                        if (heap_push(&evq, t + wake_latency, seq,
                                      (int32_t)pyset_pop(&parked), -1))
                            goto fail4;
                    }
                }
                seq++;
                if (heap_push(&evq, t, seq, (int32_t)th, base)) goto fail4;
                continue;
            }
            if (depth_first) { /* cilk: queue all, re-acquire own front */
                ring_t *lp = &local[th];
                for (int64_t k = base + nk - 1; k >= base; k--) {
                    t += qc;
                    if (ring_push_back(lp, k)) goto fail4;
                    if (parked.used) {
                        seq++;
                        if (heap_push(&evq, t + wake_latency, seq,
                                      (int32_t)pyset_pop(&parked), -1))
                            goto fail4;
                    }
                }
            } else { /* bf: shared FIFO in spawn order */
                for (int64_t k = base; k < base + nk; k++) {
                    double start = t > sl_free ? t : sl_free;
                    sl_waited += start - t;
                    t = start + lock_time;
                    sl_free = t;
                    if (ring_push_back(&shared, k)) goto fail4;
                    if (parked.used) {
                        seq++;
                        if (heap_push(&evq, t + wake_latency, seq,
                                      (int32_t)pyset_pop(&parked), -1))
                            goto fail4;
                    }
                }
            }
            seq++;
            if (heap_push(&evq, t, seq, (int32_t)th, -1)) goto fail4;
            continue;
        }

        /* ---- leaf: propagate completion up the tree ---- */
        live--;
        int64_t node = task;
        while (1) {
            int64_t parent = par[node];
            if (parent < 0)
                break;
            int64_t pd = --pending[parent];
            if (pd > 0)
                break;
            if (phase[parent] == 0 && npw[parent]) {
                /* taskwait passed: spawn the parallel combine wave */
                phase[parent] = 1;
                int64_t k = npw[parent];
                int64_t fp0 = fpw[parent];
                pending[parent] = k;
                live += k;
                t += spawn_time * (double)k;
                if (depth_first) {
                    double qc = (rdn < 0) ? qop_time
                        : qop_time * (1.0 + hop_lambda_steal *
                                      (double)node_dist[core_node[cores[th]] * NN + rdn]);
                    ring_t *lp = &local[th];
                    for (int64_t j = fp0 + k - 1; j >= fp0; j--) {
                        t += qc;
                        if (ring_push_back(lp, j)) goto fail4;
                        if (parked.used) {
                            seq++;
                            if (heap_push(&evq, t + wake_latency, seq,
                                          (int32_t)pyset_pop(&parked), -1))
                                goto fail4;
                        }
                    }
                } else {
                    for (int64_t j = fp0 + k - 1; j >= fp0; j--) {
                        double start = t > sl_free ? t : sl_free;
                        sl_waited += start - t;
                        t = start + lock_time;
                        sl_free = t;
                        if (ring_push_back(&shared, j)) goto fail4;
                        if (parked.used) {
                            seq++;
                            if (heap_push(&evq, t + wake_latency, seq,
                                          (int32_t)pyset_pop(&parked), -1))
                                goto fail4;
                        }
                    }
                }
                break;
            }
            double w2 = wpo[parent];
            if (w2 > 0.0) {
                /* join continuation with the parent's locality profile */
                int64_t pn2 = exec_node[parent];
                double pen2 = mu_lam * (fr[parent] * root_dist[n] +
                                        fp[parent] * (double)node_dist[n * NN + pn2]);
                double c2 = w2 * (1.0 + pen2);
                if (has_faults)
                    c2 = c2 * fspeed[core];
                remote += w2 * pen2;
                total_exec += c2;
                agg_node_remote[n] += w2 * pen2;
                t += c2;
            }
            node = parent;
        }
        if (t > makespan)
            makespan = t;
        seq++;
        if (heap_push(&evq, t, seq, (int32_t)th, -1)) goto fail4;
    }

    if (status == 0 && executed != n_tasks)
        status = 2;             /* loop drained with work stranded */
    if (status != 1)
        last_t = makespan;
    dout[0] = makespan;
    dout[1] = remote;
    dout[2] = total_exec;
    dout[3] = sl_waited;
    dout[4] = fault_lost;
    dout[5] = last_t;
    iout[0] = steals;
    iout[1] = failed;
    iout[2] = reclaimed;
    iout[3] = reexec;
    iout[4] = executed;
    iout[5] = steps;
    iout[6] = status;
    rc = 0;

fail4:
    pyset_free(&parked);
fail3:
    free(evq.e);
fail2:
    free(shared.buf);
fail1:
    if (local)
        for (int64_t i = 0; i < T; i++)
            free(local[i].buf);
    free(wcur);
    free(local); free(dl_free); free(uidx); free(order);
    free(phase); free(exec_node); free(pending);
    return rc;
}
