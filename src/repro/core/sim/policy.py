"""Declarative scheduler-policy layer for the NANOS simulator.

A scheduler is no longer an opaque string dispatched through parallel
if/elif chains in the runtime and both engines — it is a
:class:`SchedulerSpec`: a small set of orthogonal fields

  * ``queue``  — where spawned tasks wait:
      ``"shared"``  one global FIFO behind a serializing lock (the
                    Nanos breadth-first pool);
      ``"local"``   per-thread LIFO deques with work stealing.
  * ``spawn``  — what the spawning thread does next:
      ``"child_first"``   dive into the first child immediately
                          (work-first / depth-first execution);
      ``"parent_first"``  queue every child and continue the parent
                          (re-acquiring from its own pool).
  * ``victim`` — how an idle thread sweeps victims (``"local"`` queues
      only):
      ``"none"``         never steal (only meaningful with ``"shared"``);
      ``"random"``       fresh uniform permutation of all other threads
                         per sweep (stock cilk/wf);
      ``"dist_id"``      static: hop distance asc, thread id asc ties
                         (the paper's DFWSPT);
      ``"dist_random"``  hop distance asc, ties re-randomized per sweep
                         (the paper's DFWSRPT);
      ``"node_hier"``    hierarchical: own NUMA node first, then
                         outward tier by tier; equally-distant *nodes*
                         are visited in fresh random order per sweep but
                         each node's threads are probed together
                         (id asc) before moving on — steals concentrate
                         node-by-node instead of scattering over a tier.

A spec is compiled **once** per (topology, thread binding) into a
:class:`VictimPlan` — a per-thread list of *shuffle groups*, each a list
of *units*, each a contiguous run of victim ids. One sweep emits the
groups in order; a group with more than one unit has its unit order
freshly shuffled (one ``RandomState.shuffle`` of the unit list — draw
consumption therefore depends only on the unit count, which is how the
five stock schedulers remain bit-exact against the seed fixtures). The
same plan drives both engines: the Python loop interprets the
pre-lowered group list, the C kernel walks the flattened
``group_off/unit_off/victim_off/victims`` arrays.

Registering a new scheduler is one call — no engine edits::

    from repro.core.sim import policy
    policy.register(policy.SchedulerSpec(
        "mysched", queue="local", spawn="child_first",
        victim="node_hier"))
    simulate(topo, cores, wl, "mysched")
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from ..topology import Topology, lazy_cache

__all__ = [
    "SchedulerSpec", "VictimPlan", "SCHEDULERS",
    "register", "get_spec", "compile_victim_plan",
    "QUEUES", "SPAWNS", "VICTIMS",
]

QUEUES = ("shared", "local")
SPAWNS = ("child_first", "parent_first")
VICTIMS = ("none", "random", "dist_id", "dist_random", "node_hier")

# Python-engine group tags (see VictimPlan.py_groups)
GROUP_STATIC = 0    # payload: flat victim list, emitted as-is
GROUP_FLAT = 1      # payload: flat victim list, shuffled per sweep
GROUP_UNITS = 2     # payload: list of victim-run lists; unit order
                    # shuffled per sweep, runs emitted intact


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """A scheduler as orthogonal policy fields (see module docstring)."""
    name: str
    queue: str = "local"
    spawn: str = "child_first"
    victim: str = "random"

    def __post_init__(self):
        if self.queue not in QUEUES:
            raise ValueError(f"queue={self.queue!r}: expected one of {QUEUES}")
        if self.spawn not in SPAWNS:
            raise ValueError(f"spawn={self.spawn!r}: expected one of {SPAWNS}")
        if self.victim not in VICTIMS:
            raise ValueError(
                f"victim={self.victim!r}: expected one of {VICTIMS}")
        if self.queue == "shared" and self.victim != "none":
            raise ValueError("a shared-queue scheduler has no victim sweep; "
                             "use victim='none'")
        if self.queue == "shared" and self.spawn != "parent_first":
            raise ValueError("child_first requires per-thread local queues")


class VictimPlan:
    """Compiled per-thread victim sweep program (both engine forms).

    ``py_groups[th]``: list of ``(tag, payload)`` groups (tags above).
    ``static_order[th]``: the full sweep as one list when no group ever
    shuffles (so the hot loop skips list building entirely), else None.
    ``flat()``: lazily flattened int64 arrays for the C kernel —
    ``group_off`` (T+1), ``unit_off`` (G+1), ``victim_off`` (U+1),
    ``victims`` (total victim slots).
    """

    __slots__ = ("T", "groups", "py_groups", "static_order", "_flat")

    def __init__(self, T: int, groups: list[list[list[int]]]):
        # groups[th] = list of groups; each group = list of units;
        # each unit = list of victim ids.
        self.T = T
        self.groups = groups
        self.py_groups = []
        self.static_order = []
        for per_thread in groups:
            lowered = []
            static = True
            for units in per_thread:
                if len(units) <= 1:
                    lowered.append((GROUP_STATIC,
                                    [v for u in units for v in u]))
                elif all(len(u) == 1 for u in units):
                    lowered.append((GROUP_FLAT, [u[0] for u in units]))
                    static = False
                else:
                    lowered.append((GROUP_UNITS, [list(u) for u in units]))
                    static = False
            self.py_groups.append(lowered)
            self.static_order.append(
                [v for _, payload in lowered for v in payload]
                if static else None)
        self._flat = None

    def flat(self):
        if self._flat is None:
            import numpy as np
            group_off = [0]
            unit_off = [0]
            victim_off = [0]
            victims: list[int] = []
            for per_thread in self.groups:
                for units in per_thread:
                    for u in units:
                        victims.extend(u)
                        victim_off.append(len(victims))
                    unit_off.append(len(victim_off) - 1)
                group_off.append(len(unit_off) - 1)
            self._flat = (
                np.ascontiguousarray(group_off, dtype=np.int64),
                np.ascontiguousarray(unit_off, dtype=np.int64),
                np.ascontiguousarray(victim_off, dtype=np.int64),
                np.ascontiguousarray(victims, dtype=np.int64),
            )
        return self._flat


def _victim_groups(victim: str, topo: Topology,
                   cores: Sequence[int]) -> list[list[list[int]]]:
    """Build the raw group/unit/victim nesting for one policy."""
    T = len(cores)
    dist = topo.core_distance_matrix()
    core_node = topo.core_node
    out: list[list[list[int]]] = []
    for th in range(T):
        others = [v for v in range(T) if v != th]
        if victim == "none" or not others:
            out.append([])
        elif victim == "random":
            # one group of singleton units, ascending id — a sweep is one
            # shuffle of T-1 elements, exactly the stock cilk/wf draw.
            out.append([[[v] for v in others]])
        elif victim == "dist_id":
            order = sorted(others,
                           key=lambda v: (dist[cores[th], cores[v]], v))
            out.append([[order]])  # one group, one unit: fully static
        elif victim == "dist_random":
            by_d: dict[int, list[int]] = {}
            for v in others:
                by_d.setdefault(int(dist[cores[th], cores[v]]), []).append(v)
            # one group per distance tier (asc), singleton units — one
            # shuffle per tier of tier-size elements, the DFWSRPT draws.
            out.append([[[v] for v in by_d[d]] for d in sorted(by_d)])
        elif victim == "node_hier":
            by_d = {}
            for v in others:
                by_d.setdefault(int(dist[cores[th], cores[v]]), []).append(v)
            per_thread = []
            for d in sorted(by_d):
                by_node: dict[int, list[int]] = {}
                for v in by_d[d]:
                    by_node.setdefault(int(core_node[cores[v]]), []).append(v)
                per_thread.append(list(by_node.values()))
            out.append(per_thread)
        else:  # pragma: no cover - guarded by SchedulerSpec validation
            raise ValueError(f"unknown victim policy {victim!r}")
    return out


def compile_victim_plan(spec: SchedulerSpec, topo: Topology,
                        thread_cores: Sequence[int]) -> VictimPlan:
    """Compile (and cache) the victim plan for a spec on a thread binding.

    The cache lives on the (frozen, immutable) topology object, keyed by
    the victim policy and the exact core binding — a benchmark sweep
    re-uses one plan across every (workload, seed, placement) config
    that shares a binding.
    """
    cores = tuple(int(c) for c in thread_cores)
    cache = lazy_cache(topo, "_victim_plan_cache")
    key = (spec.victim, cores)
    plan = cache.get(key)
    if plan is None:
        # persist the raw nesting across processes keyed by (topology
        # fingerprint, victim policy, binding); VictimPlan's derived
        # forms (py_groups/static_order/flat) recompute deterministically
        from .compile_cache import digest_key, get_cache
        pcache = get_cache()
        pkey = None
        groups = None
        if pcache is not None:
            pkey = digest_key("victim_plan", topo.fingerprint(),
                              spec.victim, cores)
            groups = pcache.get_victim_groups(pkey)
            if groups is not None and len(groups) != len(cores):
                groups = None
        if groups is None:
            groups = _victim_groups(spec.victim, topo, cores)
            if pcache is not None:
                pcache.put_victim_groups(pkey, groups)
        plan = VictimPlan(len(cores), groups)
        cache[key] = plan
    return plan


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

SCHEDULERS: dict[str, SchedulerSpec] = {}


def register(spec: SchedulerSpec, *, replace: bool = False) -> SchedulerSpec:
    """Register ``spec`` under ``spec.name``; returns it for chaining."""
    if not replace and spec.name in SCHEDULERS:
        raise ValueError(f"scheduler {spec.name!r} already registered "
                         "(pass replace=True to override)")
    SCHEDULERS[spec.name] = spec
    return spec


def get_spec(scheduler: "str | SchedulerSpec") -> SchedulerSpec:
    """Resolve a scheduler name (or pass a spec through)."""
    if isinstance(scheduler, SchedulerSpec):
        return scheduler
    spec = SCHEDULERS.get(scheduler)
    if spec is None:
        raise ValueError(f"unknown scheduler {scheduler!r}; registered: "
                         f"{sorted(SCHEDULERS)}")
    return spec


# The three stock Nanos schedulers the paper benchmarks against, the two
# NUMA-aware schedulers it contributes, and the hierarchical variant this
# layer makes expressible (Thibault et al. / Wittmann & Hager style).
register(SchedulerSpec("bf", queue="shared", spawn="parent_first",
                       victim="none"))
register(SchedulerSpec("cilk", queue="local", spawn="parent_first",
                       victim="random"))
register(SchedulerSpec("wf", queue="local", spawn="child_first",
                       victim="random"))
register(SchedulerSpec("dfwspt", queue="local", spawn="child_first",
                       victim="dist_id"))
register(SchedulerSpec("dfwsrpt", queue="local", spawn="child_first",
                       victim="dist_random"))
register(SchedulerSpec("dfwshier", queue="local", spawn="child_first",
                       victim="node_hier"))
