"""Declarative execution contexts: who runs where, where data lives.

The paper's contribution is two-sided — a priority-based *thread
allocation* method (§IV) and NUMA-aware *task scheduling* (§VI) — over
an explicit first-touch *data placement* model (§V.B). The scheduling
side became declarative in ``policy.py`` (:class:`SchedulerSpec`); this
module does the same for the other two sides, the way BubbleSched
treats scheduling strategies as pluggable policies over a hierarchical
machine model:

  * :class:`BindingSpec` — how N threads map to cores:
      ``"paper"``      the paper's priority-based allocation
                       (:func:`repro.core.priority.allocate_threads`);
      ``"linear"``     cores 0..N-1 in id order (baseline Nanos:
                       whatever the OS enumerates first);
      ``"scatter"``    round-robin across NUMA nodes (one core per node
                       per round, node/core ids ascending);
      ``"node_fill"``  fill each node's cores before moving to the
                       next (node/core ids ascending);
      explicit         a literal core list (``"cores:0,2,4"`` or any
                       int sequence).

  * :class:`PlacementSpec` — where the benchmark's root arrays live:
      ``"first_touch"``  the master thread's node (Linux first-touch);
      ``"spill:K"``      K-node first-touch spill from the *master's*
                         node, closest-first with priority tie-breaks
                         (the paper's §V.B model under NUMA-aware
                         allocation);
      ``"spill:K@N"``    K-node spill from explicit node N with
                         baseline node-id tie-breaks (stock Linux — the
                         paper's unmodified-Nanos variant);
      ``"interleave"``   pages interleaved over every node;
      explicit           literal node(s) (``"node:3"``, ``"nodes:1,3"``
                         or any int / int sequence).

Both are frozen dataclasses with name→spec registries
(:data:`BINDINGS` / :data:`PLACEMENTS`) mirroring ``SCHEDULERS``, and
both *lower* — once per (topology, thread count, seed), cached on the
topology like ``_root_dist_cache`` — into plain core/node tuples that
the engines consume.

An :class:`ExecContext` is the compiled pair plus the runtime-data and
migration knobs: one immutable value that fully answers "who runs
where, where does data live" for a simulation.  ``simulate()`` and
``run_sweep()`` consume ``ExecContext`` internally; the
:class:`~.machine.Machine` facade compiles and caches them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..placement import first_touch_spill
from ..priority import allocate_threads, priorities
from ..topology import Topology, lazy_cache
from .faults import get_faults

__all__ = [
    "BindingSpec", "PlacementSpec", "ExecContext",
    "BINDINGS", "PLACEMENTS",
    "register_binding", "register_placement",
    "get_binding", "get_placement",
    "BINDING_KINDS", "PLACEMENT_KINDS",
]

BINDING_KINDS = ("paper", "linear", "scatter", "node_fill", "explicit")
PLACEMENT_KINDS = ("first_touch", "spill", "interleave", "explicit")
SPILL_TIES = ("priority", "id")


# ----------------------------------------------------------------------
# BindingSpec
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BindingSpec:
    """How ``num_threads`` threads map to cores (see module docstring).

    ``lower()`` resolves the spec on a concrete topology into a core
    tuple (index = thread id, thread 0 = master). Lowerings are cached
    on the topology per (spec, T, seed); only ``"paper"`` consumes the
    seed (its tie-breaks are randomized like the paper's).
    """
    name: str
    kind: str = "paper"
    cores: Optional[tuple] = None     # for kind="explicit"

    def __post_init__(self):
        if self.kind not in BINDING_KINDS:
            raise ValueError(
                f"binding kind={self.kind!r}: expected one of {BINDING_KINDS}")
        if self.kind == "explicit":
            if not self.cores:
                raise ValueError("explicit binding needs a non-empty "
                                 "core tuple")
            object.__setattr__(self, "cores",
                               tuple(int(c) for c in self.cores))
        elif self.cores is not None:
            raise ValueError(f"binding kind={self.kind!r} takes no "
                             "explicit core list")

    def lower(self, topo: Topology, num_threads: Optional[int] = None,
              seed: int = 0) -> tuple:
        """Resolve to a core tuple on ``topo`` (cached on the topology)."""
        if self.kind == "explicit":
            if num_threads is not None and num_threads != len(self.cores):
                raise ValueError(
                    f"binding {self.name!r} pins {len(self.cores)} cores "
                    f"but threads={num_threads} was requested")
            cores = self.cores
            bad = [c for c in cores if not 0 <= c < topo.num_cores]
            if bad:
                raise ValueError(f"binding {self.name!r}: cores {bad} "
                                 f"outside topology ({topo.num_cores} cores)")
            if len(set(cores)) != len(cores):
                raise ValueError(f"binding {self.name!r}: duplicate cores")
            return cores
        if num_threads is None:
            raise ValueError(f"binding {self.name!r} needs threads=N")
        if not 1 <= num_threads <= topo.num_cores:
            raise ValueError(
                f"threads={num_threads} out of range for {topo.name} "
                f"({topo.num_cores} cores)")
        cache = lazy_cache(topo, "_binding_cache")
        key = (self, num_threads, seed if self.kind == "paper" else 0)
        cores = cache.get(key)
        if cores is None:
            # the paper binding's priority allocation is the one
            # non-trivial lowering — persist it across processes keyed
            # by (topology fingerprint, spec, T, seed)
            pcache = pkey = None
            if self.kind == "paper":
                from .compile_cache import digest_key, get_cache
                pcache = get_cache()
                if pcache is not None:
                    pkey = digest_key("binding", topo.fingerprint(),
                                      repr(self), num_threads, seed)
                    stored = pcache.get_int_tuple("contexts", pkey)
                    if stored is not None and len(stored) == num_threads:
                        cache[key] = stored
                        return stored
            cores = self._lower_uncached(topo, num_threads, seed)
            cache[key] = cores
            if pcache is not None:
                pcache.put_int_tuple("contexts", pkey, cores)
        return cores

    def _lower_uncached(self, topo: Topology, T: int, seed: int) -> tuple:
        if self.kind == "paper":
            return tuple(allocate_threads(topo, T, seed=seed))
        if self.kind == "linear":
            return tuple(range(T))
        core_ids = np.arange(topo.num_cores)
        if self.kind == "node_fill":
            order = np.lexsort((core_ids, topo.core_node))
            return tuple(int(c) for c in order[:T])
        if self.kind == "scatter":
            # round-robin: one core per node per round, node ids asc,
            # cores within a node in id order; exhausted nodes skipped.
            per_node = [topo.cores_on_node(n)
                        for n in range(topo.num_nodes)]
            out: list = []
            while len(out) < T:
                for q in per_node:
                    if q and len(out) < T:
                        out.append(q.pop(0))
            return tuple(out)
        raise ValueError(f"unknown binding kind {self.kind!r}"
                         )  # pragma: no cover - guarded in __post_init__


# ----------------------------------------------------------------------
# PlacementSpec
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Where the benchmark's root arrays live (see module docstring).

    ``spill_nodes`` is the spill-set size K (≈ dataset GB / node GB,
    paper §V); ``start`` is the first-touch node — ``"master"`` (the
    master thread's node, resolved at lower time) or an explicit node
    id; ``ties`` picks the fallback walk when several nodes are equally
    close: ``"priority"`` (the paper's prioritized allocation) or
    ``"id"`` (stock Linux walks node ids).
    """
    name: str
    kind: str = "first_touch"
    spill_nodes: int = 1
    start: "str | int" = "master"
    ties: str = "priority"
    nodes: Optional[tuple] = None     # for kind="explicit"

    def __post_init__(self):
        if self.kind not in PLACEMENT_KINDS:
            raise ValueError(f"placement kind={self.kind!r}: expected one "
                             f"of {PLACEMENT_KINDS}")
        if self.ties not in SPILL_TIES:
            raise ValueError(f"placement ties={self.ties!r}: expected one "
                             f"of {SPILL_TIES}")
        if self.kind == "spill":
            if self.spill_nodes < 1:
                raise ValueError(f"spill needs ≥1 node, got "
                                 f"{self.spill_nodes}")
            if self.start != "master" and not isinstance(self.start, int):
                raise ValueError(f"spill start={self.start!r}: expected "
                                 "'master' or a node id")
        if self.kind == "explicit":
            if not self.nodes:
                raise ValueError("explicit placement needs a non-empty "
                                 "node tuple")
            object.__setattr__(self, "nodes",
                               tuple(int(n) for n in self.nodes))
        elif self.nodes is not None:
            raise ValueError(f"placement kind={self.kind!r} takes no "
                             "explicit node list")

    def lower(self, topo: Topology, master_core: int) -> Optional[tuple]:
        """Resolve to the root-data node tuple (``None`` = first-touch
        on the master's node, the engine default). Cached on the
        topology per (spec, master node)."""
        if self.kind == "first_touch":
            return None
        if self.kind == "explicit":
            bad = [n for n in self.nodes if not 0 <= n < topo.num_nodes]
            if bad:
                raise ValueError(f"placement {self.name!r}: nodes {bad} "
                                 f"outside topology ({topo.num_nodes} nodes)")
            return self.nodes
        if self.kind == "interleave":
            return tuple(range(topo.num_nodes))
        # kind == "spill"
        if self.spill_nodes > topo.num_nodes:
            raise ValueError(
                f"placement {self.name!r}: spill over {self.spill_nodes} "
                f"nodes but {topo.name} has {topo.num_nodes}")
        start = (int(topo.core_node[master_core])
                 if self.start == "master" else int(self.start))
        if not 0 <= start < topo.num_nodes:
            raise ValueError(f"placement {self.name!r}: start node {start} "
                             f"outside topology ({topo.num_nodes} nodes)")
        cache = lazy_cache(topo, "_placement_cache")
        key = (self, start)
        nodes = cache.get(key)
        if nodes is None:
            from .compile_cache import digest_key, get_cache
            pcache = get_cache()
            pkey = None
            if pcache is not None:
                pkey = digest_key("placement", topo.fingerprint(),
                                  repr(self), start)
                stored = pcache.get_int_tuple("contexts", pkey)
                if stored is not None and len(stored) == self.spill_nodes:
                    cache[key] = stored
                    return stored
            pr = priorities(topo) if self.ties == "priority" else None
            nodes = tuple(first_touch_spill(topo, start, self.spill_nodes,
                                            pr))
            cache[key] = nodes
            if pcache is not None:
                pcache.put_int_tuple("contexts", pkey, nodes)
        return nodes


# ----------------------------------------------------------------------
# Registries + string forms
# ----------------------------------------------------------------------

BINDINGS: dict = {}
PLACEMENTS: dict = {}


def register_binding(spec: BindingSpec, *,
                     replace: bool = False) -> BindingSpec:
    """Register ``spec`` under ``spec.name``; returns it for chaining."""
    if not replace and spec.name in BINDINGS:
        raise ValueError(f"binding {spec.name!r} already registered "
                         "(pass replace=True to override)")
    BINDINGS[spec.name] = spec
    return spec


def register_placement(spec: PlacementSpec, *,
                       replace: bool = False) -> PlacementSpec:
    """Register ``spec`` under ``spec.name``; returns it for chaining."""
    if not replace and spec.name in PLACEMENTS:
        raise ValueError(f"placement {spec.name!r} already registered "
                         "(pass replace=True to override)")
    PLACEMENTS[spec.name] = spec
    return spec


def _int_list(text: str, what: str) -> tuple:
    try:
        return tuple(int(p) for p in text.split(",") if p != "")
    except ValueError:
        raise ValueError(f"malformed {what} list {text!r}") from None


def get_binding(binding) -> BindingSpec:
    """Resolve a binding: a spec, a registered/parametrized name, or an
    explicit core sequence."""
    if isinstance(binding, BindingSpec):
        return binding
    if isinstance(binding, str):
        spec = BINDINGS.get(binding)
        if spec is not None:
            return spec
        if binding.startswith("cores:"):
            return BindingSpec(binding, kind="explicit",
                              cores=_int_list(binding[6:], "core"))
        raise ValueError(f"unknown binding {binding!r}; registered: "
                         f"{sorted(BINDINGS)} (or 'cores:a,b,...')")
    if isinstance(binding, (list, tuple, np.ndarray, range)):
        cores = tuple(int(c) for c in binding)
        return BindingSpec(f"cores:{','.join(map(str, cores))}",
                           kind="explicit", cores=cores)
    raise TypeError(f"cannot interpret {binding!r} as a thread binding")


def get_placement(placement) -> PlacementSpec:
    """Resolve a placement: a spec, a registered/parametrized name
    (``spill:K``, ``spill:K@N``, ``node:N``, ``nodes:a,b``), an explicit
    node / node sequence, or ``None`` (first-touch)."""
    if placement is None:
        return PLACEMENTS["first_touch"]
    if isinstance(placement, PlacementSpec):
        return placement
    if isinstance(placement, str):
        spec = PLACEMENTS.get(placement)
        if spec is not None:
            return spec
        if placement.startswith("spill:"):
            body = placement[6:]
            if "@" in body:
                k_s, _, n_s = body.partition("@")
                try:
                    k, start = int(k_s), int(n_s)
                except ValueError:
                    raise ValueError(
                        f"malformed placement {placement!r}; expected "
                        "'spill:K@N'") from None
                # pinning the start node models stock Linux first-touch:
                # the fallback walk is by node id, not priority
                return PlacementSpec(placement, kind="spill", spill_nodes=k,
                                     start=start, ties="id")
            try:
                k = int(body)
            except ValueError:
                raise ValueError(f"malformed placement {placement!r}; "
                                 "expected 'spill:K'") from None
            return PlacementSpec(placement, kind="spill", spill_nodes=k)
        if placement.startswith("node:"):
            return PlacementSpec(placement, kind="explicit",
                                 nodes=_int_list(placement[5:], "node"))
        if placement.startswith("nodes:"):
            return PlacementSpec(placement, kind="explicit",
                                 nodes=_int_list(placement[6:], "node"))
        raise ValueError(f"unknown placement {placement!r}; registered: "
                         f"{sorted(PLACEMENTS)} (or 'spill:K', 'spill:K@N', "
                         "'node:N', 'nodes:a,b,...')")
    if isinstance(placement, (int, np.integer)):
        return PlacementSpec(f"node:{int(placement)}", kind="explicit",
                             nodes=(int(placement),))
    if isinstance(placement, (list, tuple, np.ndarray, range)):
        nodes = tuple(int(n) for n in placement)
        return PlacementSpec(f"nodes:{','.join(map(str, nodes))}",
                             kind="explicit", nodes=nodes)
    raise TypeError(f"cannot interpret {placement!r} as a data placement")


register_binding(BindingSpec("paper", kind="paper"))
register_binding(BindingSpec("linear", kind="linear"))
register_binding(BindingSpec("scatter", kind="scatter"))
register_binding(BindingSpec("node_fill", kind="node_fill"))

register_placement(PlacementSpec("first_touch", kind="first_touch"))
register_placement(PlacementSpec("interleave", kind="interleave"))


# ----------------------------------------------------------------------
# ExecContext
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecContext:
    """A compiled execution context: binding + placement lowered onto a
    topology, plus the runtime-data and migration knobs.

    ``thread_cores`` / ``root_data_nodes`` are the lowered tuples the
    engines consume; ``binding`` / ``placement`` keep the declarative
    identity for display and grid keys. Build with
    :meth:`ExecContext.compile` (full resolution + validation) or let
    :class:`~.machine.Machine` cache them.
    """
    topo: Topology
    params: object                      # SimParams (duck-typed: no cycle)
    binding: BindingSpec
    placement: PlacementSpec
    thread_cores: tuple
    root_data_nodes: Optional[tuple]
    runtime_data_node: Optional[int] = None
    migration_rate: float = 0.0
    bind_seed: int = 0
    # declarative fault models (FaultSpec tuple); lowered per simulation
    # seed into a compiled FaultPlan by the engine entry point.
    faults: tuple = ()

    @property
    def threads(self) -> int:
        return len(self.thread_cores)

    @property
    def master_core(self) -> int:
        return self.thread_cores[0]

    @property
    def master_node(self) -> int:
        return int(self.topo.core_node[self.thread_cores[0]])

    def label(self) -> str:
        """Compact display identity, e.g. ``paper/spill:2``."""
        return f"{self.binding.name}/{self.placement.name}"

    def fingerprint(self) -> str:
        """Stable content digest of everything this context makes the
        engines observe: the topology fingerprint, the *lowered*
        core/node tuples (so ``binding="paper"`` and an explicit core
        list that lowers identically share one identity), the
        runtime-data/migration knobs, the fault model fields, and the
        cost-model constants from ``params``. Execution knobs that
        cannot change a result (``SimParams.workers``,
        ``SimParams.trace`` — tracing is purely observational) are
        excluded.
        The persistent result store keys cells on this. Cached (the
        context is frozen and shared across sweep cells).
        """
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            import hashlib
            pfields = tuple(
                (f.name, getattr(self.params, f.name))
                for f in dataclasses.fields(self.params)
                if f.name not in ("workers", "trace"))
            material = (self.topo.fingerprint(), self.thread_cores,
                        self.root_data_nodes, self.runtime_data_node,
                        self.migration_rate,
                        tuple(dataclasses.astuple(f) for f in self.faults),
                        pfields)
            fp = hashlib.blake2b(repr(material).encode(),
                                 digest_size=16).hexdigest()
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    @classmethod
    def compile(cls, topo: Topology, params, threads: Optional[int] = None,
                binding="paper", placement="first_touch",
                runtime_data="local", migration_rate: float = 0.0,
                bind_seed: int = 0, faults=()) -> "ExecContext":
        """Resolve + lower + validate a declarative context description.

        ``runtime_data``: ``"local"`` (each thread's runtime structures
        on its own node — the paper's modification), ``"master"`` (all
        on the master's node), or an explicit node id (baseline Nanos
        first-touches everything on the initializing node).

        ``faults``: fault model(s) — specs, parametrized strings
        (``"straggler:0.5@2"``, ``"preempt:2@10"``, ``"fail:1"``), or a
        sequence composing several. Validated here; the stochastic
        lowering into a :class:`~.faults.FaultPlan` happens per
        simulation seed at run time.
        """
        bspec = get_binding(binding)
        pspec = get_placement(placement)
        cores = bspec.lower(topo, threads, seed=bind_seed)
        nodes = pspec.lower(topo, cores[0])
        fault_specs = get_faults(faults)
        for fspec in fault_specs:
            fspec.validate(topo, len(cores))
        if runtime_data == "local" or runtime_data is None:
            rt_node = None
        elif runtime_data == "master":
            rt_node = int(topo.core_node[cores[0]])
        elif isinstance(runtime_data, (int, np.integer)):
            rt_node = int(runtime_data)
            if not 0 <= rt_node < topo.num_nodes:
                raise ValueError(f"runtime_data node {rt_node} outside "
                                 f"topology ({topo.num_nodes} nodes)")
        else:
            raise ValueError(f"runtime_data={runtime_data!r}: expected "
                             "'local', 'master', or a node id")
        if not 0.0 <= migration_rate <= 1.0:
            raise ValueError(f"migration_rate={migration_rate} outside "
                             "[0, 1]")
        return cls(topo=topo, params=params, binding=bspec, placement=pspec,
                   thread_cores=cores, root_data_nodes=nodes,
                   runtime_data_node=rt_node, migration_rate=migration_rate,
                   bind_seed=bind_seed, faults=fault_specs)

    @classmethod
    def from_raw(cls, topo: Topology, params, thread_cores: Sequence[int],
                 root_data_nodes=None, runtime_data_node: Optional[int] = None,
                 migration_rate: float = 0.0) -> "ExecContext":
        """Wrap legacy ``simulate()`` arguments without re-lowering.

        The binding/placement identities become explicit specs; no
        registry parsing, no validation beyond normalization — this is
        the hot-path shim under the positional ``simulate()``.
        """
        cores = tuple(int(c) for c in thread_cores)
        if root_data_nodes is None:
            nodes = None
            pspec = PLACEMENTS["first_touch"]
        else:
            if isinstance(root_data_nodes, (int, np.integer)):
                nodes = (int(root_data_nodes),)
            else:
                nodes = tuple(int(n) for n in root_data_nodes)
            pspec = PlacementSpec(
                f"nodes:{','.join(map(str, nodes))}", kind="explicit",
                nodes=nodes)
        bspec = BindingSpec(f"cores:{','.join(map(str, cores))}",
                            kind="explicit", cores=cores)
        return cls(topo=topo, params=params, binding=bspec, placement=pspec,
                   thread_cores=cores, root_data_nodes=nodes,
                   runtime_data_node=runtime_data_node,
                   migration_rate=migration_rate)
