"""Locality-aware MoE routing — DFWSPT/DFWSRPT inside the XLA program.

The paper's schedulers let an idle thread steal queued tasks from the
*nearest* victim (ties deterministic for DFWSPT, random for DFWSRPT). The
SPMD analogue implemented here: experts are task queues with bounded
capacity; tokens that overflow an expert's capacity are re-routed ("stolen")
to the expert whose owning device is *fewest ICI hops away* from the
overloaded one, in a precomputed steal order. This keeps the rescue
traffic on short links instead of letting overflow drop (quality loss) or
re-shuffle across the whole mesh (bandwidth loss).

Because XLA programs are static, the steal order is baked in ahead of
time from the topology (``expert_steal_table``) — the DFWSRPT variant
bakes the random tie-breaks at trace time from a seed, which is exactly
the paper's "randomly choose its victim" decision frozen per program.

All shapes are static; everything lowers under pjit/shard_map.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .stealing import steal_order_matrix
from .topology import Topology

__all__ = ["RoutingConfig", "expert_steal_table", "route",
           "dispatch_combine_weights"]


@dataclasses.dataclass(frozen=True)
class RoutingConfig:
    num_experts: int
    top_k: int
    capacity: int            # per-expert token slots (per routed batch)
    steal_attempts: int = 2  # 0 = vanilla GShard-style drop-on-overflow
    policy: str = "dfwspt"   # or 'dfwsrpt'


def expert_steal_table(topo: Topology,
                       expert_device: np.ndarray,
                       policy: str = "dfwspt",
                       seed: int = 0) -> np.ndarray:
    """(E, E-1) steal order: row e = other experts by hop distance from
    the device owning e (paper's priority list, expert-granular).

    expert_device: (E,) physical device (== core in the topology) owning
    each expert shard.
    """
    expert_device = np.asarray(expert_device, np.int64)
    E = expert_device.shape[0]
    dist = topo.core_distance_matrix()
    rng = np.random.RandomState(seed)
    rows = []
    for e in range(E):
        others = [x for x in range(E) if x != e]
        d = dist[expert_device[e], expert_device[others]]
        if policy == "dfwspt":
            key = np.lexsort((np.asarray(others), d))
        elif policy == "dfwsrpt":
            key = np.lexsort((rng.permutation(E - 1), d))
        else:
            raise ValueError(f"unknown policy {policy!r}")
        rows.append([others[i] for i in key])
    return np.asarray(rows, np.int64)


def _fill_positions(choice: jnp.ndarray, active: jnp.ndarray,
                    used: jnp.ndarray, num_experts: int, capacity: int):
    """Greedy in-order capacity fill for one routing attempt.

    choice: (T,) expert id per token; active: (T,) tokens still waiting.
    used: (E,) slots already taken. Returns (placed, position, new_used).
    """
    onehot = jax.nn.one_hot(choice, num_experts, dtype=jnp.int32)
    onehot = onehot * active[:, None].astype(jnp.int32)
    # position of each token within its chosen expert's queue
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot   # (T, E)
    pos = jnp.take_along_axis(
        pos_in_expert, choice[:, None], axis=1)[:, 0] + used[choice]
    placed = active & (pos < capacity)
    new_used = used + jnp.minimum(onehot.sum(axis=0),
                                  capacity - used)
    return placed, pos, new_used


def route(gate_logits: jnp.ndarray,
          cfg: RoutingConfig,
          steal_table: np.ndarray | None = None):
    """Top-k routing with locality-aware overflow stealing.

    Args:
      gate_logits: (T, E) router scores for a routed group.
      steal_table: (E, E-1) from :func:`expert_steal_table`. Required when
        ``cfg.steal_attempts > 0``.

    Returns dict with:
      expert:   (T, K) int32 — final expert of each (token, slot); -1 drop.
      slot:     (T, K) int32 — capacity slot within that expert; -1 drop.
      weight:   (T, K) f32   — combine weights (renormalized gate probs).
      aux_loss: scalar load-balancing auxiliary (Switch-style).
      drop_fraction: scalar — fraction of (token, slot) pairs dropped.
    """
    T, E = gate_logits.shape
    if E != cfg.num_experts:
        raise ValueError(f"gate width {E} != num_experts {cfg.num_experts}")
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)          # (T, K)

    # Switch-Transformer auxiliary load-balance loss.
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), 0)
    router_prob = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(density * router_prob)

    if cfg.steal_attempts > 0:
        if steal_table is None:
            raise ValueError("steal_attempts > 0 requires a steal_table")
        table = jnp.asarray(steal_table, jnp.int32)         # (E, E-1)

    # Flatten (token, k-slot) pairs; earlier k-slots get priority, matching
    # the paper's depth-first "own queue first" preference.
    flat_e = top_e.T.reshape(-1)                            # (K*T,)
    flat_active = jnp.ones((cfg.top_k * T,), bool)
    flat_expert = jnp.full((cfg.top_k * T,), -1, jnp.int32)
    flat_slot = jnp.full((cfg.top_k * T,), -1, jnp.int32)
    used = jnp.zeros((E,), jnp.int32)

    choice = flat_e
    for attempt in range(cfg.steal_attempts + 1):
        placed, pos, used = _fill_positions(choice, flat_active, used,
                                            E, cfg.capacity)
        flat_expert = jnp.where(placed, choice, flat_expert)
        flat_slot = jnp.where(placed, pos.astype(jnp.int32), flat_slot)
        flat_active = flat_active & ~placed
        if attempt < cfg.steal_attempts:
            # overflow tokens walk the victim list of their *current*
            # expert: nearest device first (DFWSPT/DFWSRPT).
            choice = table[choice, attempt]
    expert = flat_expert.reshape(cfg.top_k, T).T            # (T, K)
    slot = flat_slot.reshape(cfg.top_k, T).T
    keep = expert >= 0
    w = top_p * keep
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return dict(expert=expert, slot=slot, weight=w, aux_loss=aux_loss,
                drop_fraction=1.0 - jnp.mean(keep.astype(jnp.float32)))


def dispatch_combine_weights(routing: dict, num_experts: int, capacity: int):
    """Dense GShard-style tensors from a routing result.

    Returns:
      dispatch: (T, E, C) bool — token t occupies slot c of expert e.
      combine:  (T, E, C) f32  — dispatch · weight.
    """
    expert, slot, w = routing["expert"], routing["slot"], routing["weight"]
    T, K = expert.shape
    e_oh = jax.nn.one_hot(expert, num_experts, dtype=jnp.float32)  # (T,K,E)
    c_oh = jax.nn.one_hot(slot, capacity, dtype=jnp.float32)       # (T,K,C)
    combine = jnp.einsum("tke,tkc,tk->tec", e_oh, c_oh, w)
    dispatch = jnp.einsum("tke,tkc->tec", e_oh, c_oh) > 0
    return dispatch, combine
