"""NUMA-aware work-stealing victim orders (paper §VI).

Both of the paper's schedulers steal from victims ranked by hop distance
from the idle thread's core; they differ only in tie-breaking at equal
distance:

  * DFWSPT  — ties broken by ascending thread id ("threads with smaller
    id are placed first").
  * DFWSRPT — ties broken by a fresh random permutation each time the
    thread goes stealing ("victim thread is picked randomly" among the
    equally-close), which avoids convoys on the lowest-id victim.
  * DFWSHIER — the policy layer's hierarchical variant: equal-distance
    ties are randomized at *node* granularity — a sweep probes all of
    one NUMA node's threads (id asc) before moving to the next node,
    so consecutive probes share victim-node memory.

``priority_list`` builds the static DFWSPT list; ``victim_order`` yields
the per-attempt order for any policy. The same orders drive the MoE
overflow re-routing in :mod:`repro.core.routing` (the TPU adaptation),
where "threads" are expert-owning devices.
"""

from __future__ import annotations

from typing import Iterator, Sequence

import numpy as np

from .topology import Topology

__all__ = ["priority_list", "victim_order", "steal_order_matrix"]


def priority_list(topo: Topology, thread_cores: Sequence[int],
                  thread: int) -> list[int]:
    """DFWSPT static priority list for ``thread``.

    Returns other threads' ids ordered by (hop distance from this thread's
    core asc, thread id asc). This is computed once at startup, exactly as
    the paper prescribes.
    """
    me = thread_cores[thread]
    dist = topo.core_distance_matrix()
    others = [t for t in range(len(thread_cores)) if t != thread]
    return sorted(others, key=lambda t: (dist[me, thread_cores[t]], t))


def victim_order(topo: Topology, thread_cores: Sequence[int], thread: int,
                 policy: str, rng: np.random.RandomState) -> list[int]:
    """Victim id order for one stealing sweep.

    policy: 'dfwspt' (deterministic ties), 'dfwsrpt' (random ties), or
    'dfwshier' (node-granular random ties, node members contiguous).
    """
    me = thread_cores[thread]
    dist = topo.core_distance_matrix()
    others = [t for t in range(len(thread_cores)) if t != thread]
    if policy == "dfwspt":
        return sorted(others, key=lambda t: (dist[me, thread_cores[t]], t))
    if policy == "dfwsrpt":
        jitter = rng.permutation(len(thread_cores))
        return sorted(others, key=lambda t: (dist[me, thread_cores[t]], jitter[t]))
    if policy == "dfwshier":
        # One sweep of the policy layer's node_hier grouping (the same
        # code the engines compile), so an ahead-of-time order from a
        # fresh RandomState(seed) equals the engine's first sweep.
        from .sim.policy import _victim_groups
        order: list[int] = []
        for units in _victim_groups("node_hier", topo, thread_cores)[thread]:
            if len(units) > 1:
                units = list(units)
                rng.shuffle(units)
            for u in units:
                order.extend(u)
        return order
    raise ValueError(f"unknown stealing policy {policy!r}")


def steal_order_matrix(topo: Topology, thread_cores: Sequence[int],
                       policy: str = "dfwspt",
                       seed: int = 0) -> np.ndarray:
    """(T, T-1) matrix: row t = victim order for thread t.

    For 'dfwsrpt' the random tie-break is drawn once per row from ``seed``
    — this is the *ahead-of-time* form used by the TPU routing adaptation,
    where the steal order must be baked into the compiled program.
    """
    rng = np.random.RandomState(seed)
    rows = [victim_order(topo, thread_cores, t, policy, rng)
            for t in range(len(thread_cores))]
    return np.asarray(rows, np.int64)
