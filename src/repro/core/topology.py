"""Hop-distance topology models for non-uniform architectures.

The paper's machine model is a set of *locations* (cores) grouped into
*nodes* (NUMA domains) with an integer hop-distance matrix between nodes.
We reproduce that model faithfully (``Topology``), provide the paper's own
evaluation machine (SunFire X4600), and extend it to the deployment target
of this framework: multi-pod TPU slices, where intra-pod distance is ICI
torus hops and inter-pod distance is a large DCI penalty.

Everything here is pure Python/NumPy — topology modeling happens at
launch/initialization time, never inside a jitted program.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "lazy_cache",
    "sunfire_x4600",
    "tpu_pod_2d",
    "multi_pod",
    "uma",
]


def lazy_cache(topo: "Topology", attr: str) -> dict:
    """A named memo dict living on a (frozen) topology.

    Compiled artifacts keyed by immutable topology state — distance
    matrices, priority results, binding/placement lowerings, victim
    plans — cache here so every consumer sharing the topology shares
    them. ``object.__setattr__`` because the dataclass is frozen.
    """
    cache = topo.__dict__.get(attr)
    if cache is None:
        cache = {}
        object.__setattr__(topo, attr, cache)
    return cache


@dataclasses.dataclass(frozen=True)
class Topology:
    """A non-uniform machine: cores grouped into nodes, node hop distances.

    Attributes:
      name: human-readable identifier.
      core_node: (num_cores,) int array — node id of each core.
      node_distance: (num_nodes, num_nodes) int array of hop distances.
        Zero on the diagonal; symmetric. Distances between *cores* derive
        from their nodes (cores on one node are 0 hops apart, matching the
        paper's model where a node's cores share local memory).
      link_bandwidth: bandwidth (bytes/s) of a 1-hop link; used by the
        collective cost model, not by the priority algorithm.
      hop_latency: per-hop latency weight for the NUMA factor model.
    """

    name: str
    core_node: np.ndarray
    node_distance: np.ndarray
    link_bandwidth: float = 50e9
    hop_latency: float = 1.0

    def __post_init__(self):
        cn = np.asarray(self.core_node, dtype=np.int64)
        nd = np.asarray(self.node_distance, dtype=np.int64)
        object.__setattr__(self, "core_node", cn)
        object.__setattr__(self, "node_distance", nd)
        if nd.ndim != 2 or nd.shape[0] != nd.shape[1]:
            raise ValueError(f"node_distance must be square, got {nd.shape}")
        if not np.array_equal(nd, nd.T):
            raise ValueError("node_distance must be symmetric")
        if np.any(np.diag(nd) != 0):
            raise ValueError("node_distance diagonal must be zero")
        if cn.min(initial=0) < 0 or cn.max(initial=0) >= nd.shape[0]:
            raise ValueError("core_node indexes outside node_distance")

    # ------------------------------------------------------------------
    @property
    def num_cores(self) -> int:
        return int(self.core_node.shape[0])

    @property
    def num_nodes(self) -> int:
        return int(self.node_distance.shape[0])

    def core_distance(self, a: int, b: int) -> int:
        """Hop distance between two cores (0 if co-located on a node)."""
        return int(self.node_distance[self.core_node[a], self.core_node[b]])

    def core_distance_matrix(self) -> np.ndarray:
        """(num_cores, num_cores) hop distances.

        Cached on first use: the simulator and the placement/stealing
        code all hit this on their hot setup paths, and the matrix is
        immutable once the (frozen) topology exists. The cached array is
        marked read-only so no caller can corrupt the shared copy.
        """
        m = self.__dict__.get("_core_distance_matrix")
        if m is None:
            m = self.node_distance[self.core_node][:, self.core_node]
            m.flags.writeable = False
            object.__setattr__(self, "_core_distance_matrix", m)
        return m

    def max_distance(self) -> int:
        return int(self.node_distance.max())

    def hop_histogram(self, core: int) -> dict[int, int]:
        """Paper's N_i: number of *other* cores at each hop distance i."""
        d = self.core_distance_matrix()[core]
        mask = np.arange(d.shape[0]) != core
        dists, counts = np.unique(d[mask], return_counts=True)
        return {int(k): int(v) for k, v in zip(dists, counts)}

    def numa_factor(self, a: int, b: int) -> float:
        """Latency ratio remote/local for cores a, b (>= 1)."""
        return 1.0 + self.hop_latency * self.core_distance(a, b)

    def fingerprint(self) -> str:
        """Stable content digest of the machine description.

        Hashes everything the simulator's cost model can observe — the
        core→node map, the hop-distance matrix, and the scalar model
        knobs — so two topologies with equal fingerprints are
        interchangeable as cache keys (the persistent result store and
        the auto-tuner key evaluated cells on this). The name is
        *excluded*: a renamed but physically identical machine must hit
        the same cached cells. Cached on first use (the topology is
        frozen).
        """
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            import hashlib
            h = hashlib.blake2b(digest_size=16)
            h.update(np.ascontiguousarray(self.core_node).tobytes())
            h.update(np.ascontiguousarray(self.node_distance).tobytes())
            h.update(repr((float(self.link_bandwidth),
                           float(self.hop_latency))).encode())
            fp = h.hexdigest()
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    def cores_on_node(self, node: int) -> list[int]:
        return [int(c) for c in np.nonzero(self.core_node == node)[0]]

    def restrict(self, cores: Sequence[int]) -> "Topology":
        """Sub-topology over surviving cores (for elastic re-placement).

        Node ids are preserved so distances stay exact; core indices are
        re-numbered densely in the order given.
        """
        cores = list(cores)
        return Topology(
            name=f"{self.name}/restrict{len(cores)}",
            core_node=self.core_node[cores],
            node_distance=self.node_distance,
            link_bandwidth=self.link_bandwidth,
            hop_latency=self.hop_latency,
        )


# ----------------------------------------------------------------------
# Machines
# ----------------------------------------------------------------------

def uma(num_cores: int, name: str = "uma") -> Topology:
    """Uniform machine: one node, all cores local (paper §II baseline)."""
    return Topology(name, np.zeros(num_cores, np.int64), np.zeros((1, 1), np.int64))


def sunfire_x4600(cores_per_node: int = 2, num_nodes: int = 8) -> Topology:
    """The paper's evaluation machine (§V): SunFire X4600.

    8 dual-core AMD Opteron sockets on an enhanced-twisted-ladder
    HyperTransport fabric; sockets are 1–3 hops apart [Hashizume 2007].
    The ladder is *asymmetric*: the sockets that also host the I/O bridges
    spend an HT link on I/O, so end sockets have fewer coherent links and
    the hop matrix has non-uniform centrality (diameter 3, several NUMA
    factors) — exactly the property the paper's priority allocation
    exploits. We reproduce that structure: a 2×4 ladder (rungs + rails)
    with one twisted end link; sockets 0 and 6 are the I/O-constrained
    corners (degree 2).
    """
    # Socket adjacency: rungs (0-1, 2-3, 4-5, 6-7), rails (0-2, 2-4, 4-6 /
    # 1-3, 3-5, 5-7), one twisted end link (1-7). Degrees: 0,6 → 2.
    edges = [
        (0, 1), (2, 3), (4, 5), (6, 7),
        (0, 2), (2, 4), (4, 6),
        (1, 3), (3, 5), (5, 7),
        (1, 7),
    ]
    nd = _bfs_all_pairs(num_nodes, edges)
    core_node = np.repeat(np.arange(num_nodes), cores_per_node)
    return Topology("sunfire-x4600", core_node, nd, link_bandwidth=8e9)


def tpu_pod_2d(rows: int, cols: int, name: str | None = None,
               wrap: bool = True, link_bandwidth: float = 50e9) -> Topology:
    """A single TPU pod as a 2-D (twisted) torus of chips.

    Each chip is its own "node" (its HBM); hop distance = torus manhattan
    distance. This is the intra-pod ICI model (TPU v5e: 2D torus, ~50
    GB/s/link).
    """
    n = rows * cols
    rr = np.arange(rows)
    cc = np.arange(cols)
    R, C = np.meshgrid(rr, cc, indexing="ij")
    coords = np.stack([R.ravel(), C.ravel()], axis=1)  # (n, 2)
    dr = np.abs(coords[:, None, 0] - coords[None, :, 0])
    dc = np.abs(coords[:, None, 1] - coords[None, :, 1])
    if wrap:
        dr = np.minimum(dr, rows - dr)
        dc = np.minimum(dc, cols - dc)
    nd = (dr + dc).astype(np.int64)
    return Topology(name or f"tpu-pod-{rows}x{cols}",
                    np.arange(n, dtype=np.int64), nd,
                    link_bandwidth=link_bandwidth)


def multi_pod(num_pods: int, rows: int, cols: int,
              dci_hops: int | None = None,
              link_bandwidth: float = 50e9,
              dci_bandwidth: float = 6.25e9) -> Topology:
    """Multi-pod cluster: pods of (rows × cols) chips joined by DCI.

    Inter-pod distance = exit-hops + DCI penalty + entry-hops, modeled as a
    flat ``dci_hops`` (default: torus diameter + bandwidth-ratio penalty),
    matching the paper's "several NUMA factors" regime — intra-pod traffic
    is 1..(rows+cols)/2 hops, cross-pod traffic is strictly more expensive.
    """
    pod = tpu_pod_2d(rows, cols, link_bandwidth=link_bandwidth)
    n_per = pod.num_cores
    if dci_hops is None:
        diameter = (rows // 2) + (cols // 2)
        dci_hops = diameter + int(round(link_bandwidth / dci_bandwidth))
    n_nodes = num_pods * n_per
    nd = np.full((n_nodes, n_nodes), dci_hops, np.int64)
    for p in range(num_pods):
        s = slice(p * n_per, (p + 1) * n_per)
        nd[s, s] = pod.node_distance
    np.fill_diagonal(nd, 0)
    return Topology(f"tpu-{num_pods}pod-{rows}x{cols}",
                    np.arange(n_nodes, dtype=np.int64), nd,
                    link_bandwidth=link_bandwidth)


# ----------------------------------------------------------------------

def _bfs_all_pairs(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    adj: list[list[int]] = [[] for _ in range(n)]
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    nd = np.full((n, n), -1, np.int64)
    for s in range(n):
        nd[s, s] = 0
        frontier = [s]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for v in adj[u]:
                    if nd[s, v] < 0:
                        nd[s, v] = d
                        nxt.append(v)
            frontier = nxt
    if (nd < 0).any():
        raise ValueError("disconnected topology")
    return nd
