"""The paper's contribution: NUMA-aware allocation + locality scheduling.

Faithful layer: topology, priority, stealing, sim (NANOS/BOTS model).
TPU adaptation: placement (mesh layout), routing (MoE overflow stealing).
"""

from . import placement, priority, routing, stealing, topology
from .priority import allocate_threads, priorities
from .routing import RoutingConfig, expert_steal_table, route
from .topology import Topology, multi_pod, sunfire_x4600, tpu_pod_2d, uma

__all__ = [
    "placement", "priority", "routing", "stealing", "topology",
    "allocate_threads", "priorities", "RoutingConfig",
    "expert_steal_table", "route", "Topology", "multi_pod",
    "sunfire_x4600", "tpu_pod_2d", "uma",
]
