"""Sharded, atomic, async checkpointing with cross-mesh (elastic) restore.

Layout on disk::

    <dir>/step_000123/          (atomic: written as .tmp_step_000123, renamed)
        index.json              tree structure, shapes, dtypes, mesh info
        shard_<host>_<n>.npz    per-addressable-shard arrays

Key properties for thousand-node operation:
  * every host writes only its addressable shards (no gather-to-host-0);
  * ``index.json`` records the global shape + shard index maps, so a
    restore may target a *different* mesh (elastic shrink/grow): shards
    are reassembled to global arrays then re-dispatched under the new
    sharding;
  * writes go through a background thread (off the step critical path)
    and a ``.tmp`` → rename commit, so a failure mid-write never corrupts
    the latest checkpoint;
  * ``keep_last`` garbage-collects old steps after a successful commit.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _undo_void(arr: np.ndarray, dtype: np.dtype) -> np.ndarray:
    """npz round-trips ml_dtypes (bfloat16, fp8) as raw void — view back."""
    if arr.dtype.kind == "V":
        return arr.view(dtype)
    return arr


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def save(directory: str, step: int, tree: Any,
         host_id: int = 0, num_hosts: int = 1) -> str:
    """Write one checkpoint step (synchronous). Returns committed path."""
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, f".tmp_{name}_{host_id}")
    final = os.path.join(directory, name)
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(tree)
    index: dict[str, Any] = {"step": step, "arrays": {}, "num_hosts": num_hosts}
    shard_payload: dict[str, np.ndarray] = {}
    for key, leaf in flat.items():
        arr = leaf
        meta: dict[str, Any] = {
            "shape": list(np.shape(arr)),
            "dtype": str(np.asarray(jax.device_get(arr)).dtype
                         if not hasattr(arr, "dtype") else arr.dtype),
        }
        if isinstance(arr, jax.Array) and len(arr.sharding.device_set) > 1:
            # sharded: each host stores addressable shards + index map
            shards = []
            for i, sh in enumerate(arr.addressable_shards):
                sid = f"{key}::shard{sh.device.id}"
                shard_payload[sid] = np.asarray(sh.data)
                shards.append({
                    "id": sid,
                    "index": [[s.start, s.stop] if isinstance(s, slice)
                              else s for s in _index_slices(sh.index,
                                                            arr.shape)],
                })
            meta["shards"] = shards
        else:
            sid = f"{key}::full"
            shard_payload[sid] = np.asarray(jax.device_get(arr))
            meta["full"] = sid
        index["arrays"][key] = meta

    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **shard_payload)
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _index_slices(idx, shape):
    out = []
    for s, dim in zip(idx, shape):
        if isinstance(s, slice):
            out.append(slice(s.start or 0, s.stop if s.stop is not None
                             else dim))
        else:
            out.append(s)
    return out


def restore(directory: str, step: int, like: Any,
            shardings: Any | None = None) -> Any:
    """Restore a step into the structure of ``like``.

    ``like`` provides the pytree structure (arrays or ShapeDtypeStructs).
    ``shardings``: optional matching tree of NamedShardings for the
    (possibly different) target mesh — elastic restore reassembles global
    arrays from the saved shard index and re-dispatches.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    payload: dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(path)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(path, fn)) as z:
                payload.update({k: z[k] for k in z.files})

    flat_like, tdef = jax.tree_util.tree_flatten_with_path(like)
    flat_sh = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(flat_like))
    out = []
    for (pth, leaf), sh in zip(flat_like, flat_sh):
        key = "/".join(_path_str(p) for p in pth)
        meta = index["arrays"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing {key}")
        saved_dt = np.dtype(meta["dtype"])
        if "full" in meta:
            arr = _undo_void(payload[meta["full"]], saved_dt)
        else:
            arr = np.zeros(meta["shape"], dtype=saved_dt)
            for sd in meta["shards"]:
                sl = tuple(slice(p[0], p[1]) if isinstance(p, list) else p
                           for p in sd["index"])
                arr[sl] = _undo_void(payload[sd["id"]], saved_dt)
        target = np.dtype(str(getattr(leaf, "dtype", arr.dtype)))
        if arr.dtype != target:
            arr = arr.astype(target)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return tdef.unflatten(out)


class CheckpointManager:
    """Async keep-last-k manager used by the training driver."""

    def __init__(self, directory: str, keep_last: int = 3,
                 host_id: int = 0, num_hosts: int = 1):
        self.directory = directory
        self.keep_last = keep_last
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, step: int, tree: Any):
        # snapshot to host memory on the caller thread (cheap, consistent),
        # write in the background (off the critical path).
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def work():
            save(self.directory, step, host_tree,
                 self.host_id, self.num_hosts)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree: Any):
        self.wait()
        save(self.directory, step, tree, self.host_id, self.num_hosts)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any, shardings: Any | None = None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore(self.directory, step, like, shardings)

    def _gc(self):
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := _STEP_RE.match(d)))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
