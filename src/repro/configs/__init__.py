"""Assigned architecture registry: ``get(name)`` / ``ARCHS``."""

from . import (command_r_35b, granite_moe_1b_a400m, hubert_xlarge,
               jamba_1_5_large_398b, llama4_scout_17b_a16e,
               llama_3_2_vision_90b, mamba2_1_3b, qwen2_5_3b, qwen3_14b,
               stablelm_1_6b)
from .base import SHAPES, ArchConfig, ShapeSpec

_MODULES = [
    llama_3_2_vision_90b, granite_moe_1b_a400m, llama4_scout_17b_a16e,
    stablelm_1_6b, qwen2_5_3b, command_r_35b, qwen3_14b,
    jamba_1_5_large_398b, hubert_xlarge, mamba2_1_3b,
]

ARCHS: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "get", "ArchConfig", "ShapeSpec", "SHAPES"]
