"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) ff24576
vocab 65536, MoE 16e top-2, Mamba:attention 7:1 interleave
[arXiv:2403.19887; hf].

Period of 8 layers: one attention + seven Mamba2 mixers; MoE replaces the
MLP on every other layer (odd slots). Runs long_500k (sub-quadratic).
"""

from .base import ArchConfig

_PERIOD = []
for i in range(8):
    kind = "attn" if i == 0 else "mamba"
    ffn = "moe" if i % 2 == 1 else "mlp"
    _PERIOD.append((kind, ffn))

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    rope_theta=1000000.0,
    pattern=tuple(_PERIOD),
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
)
