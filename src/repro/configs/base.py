"""Architecture config schema + shape grid.

Each assigned architecture file instantiates :class:`ArchConfig` with the
exact published numbers; ``reduced()`` derives the same-family small
config for CPU smoke tests. The dry-run exercises the full configs via
ShapeDtypeStructs only.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

LayerKind = Literal["attn", "mamba", "cross"]
FfnKind = Literal["mlp", "moe", "none"]
Slot = tuple[LayerKind, FfnKind]       # (mixer kind, ffn kind)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The assigned LM shape grid (same for every arch; applicability filters
# below).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads
    pattern: tuple[Slot, ...] = (("attn", "mlp"),)

    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    attn_window: int | None = None
    attn_impl: str = "ref"           # 'ref' | 'kernel'
    # KV head replication for TP > kv_heads (launcher sets per mesh):
    # k/v are repeated this many times before use/caching so the stored
    # head dim divides the model axis (standard GQA tensor-parallel trade).
    kv_repeat: int = 1
    # long-sequence attention: above the threshold, scan over q chunks so
    # the score slab stays (chunk × Skv) instead of (Sq × Skv)
    attn_chunk: int = 1024
    attn_chunk_threshold: int = 8192
    # activation sharding constraints (set by the launcher per mesh; None
    # = let GSPMD propagate). Tuples of axis names per dim.
    attn_q_spec: tuple | None = None
    attn_kv_spec: tuple | None = None
    ssm_act_spec: tuple | None = None
    moe_group_spec: tuple | None = None
    moe_xin_spec: tuple | None = None
    moe_h_spec: tuple | None = None
    # tie each slot's weight gathers to the previous slot's output so the
    # scheduler can't hoist every FSDP all-gather to the period top
    # (bounds peak temp to ~one slot's gathered weights; trades away some
    # gather/compute overlap — see EXPERIMENTS.md §Perf)
    serialize_slot_gathers: bool = False

    # modality
    is_encoder: bool = False
    embeds_input: bool = False       # frontend stub feeds embeddings
    num_media_tokens: int = 0        # VLM patch tokens (stub)

    # embeddings / head
    tie_embeddings: bool = False

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 4096
    moe_impl: str = "einsum"         # 'einsum' | 'kernel'
    moe_shared_expert: bool = False
    moe_steal_attempts: int = 2      # paper technique; 0 = vanilla drops
    moe_steal_policy: str = "dfwspt"

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_impl: str = "ref"

    # sharding profile: '2d' (TP+FSDP) | 'ep_only' (experts on "model",
    # dense FSDP across both axes — for small-d_model MoE; §Perf)
    sharding_profile: str = "2d"

    # numerics / training
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: str = "full"              # none|full|dots
    router_aux_weight: float = 0.01
    z_loss_weight: float = 1e-4

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))
        if self.num_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not a multiple "
                f"of pattern period {len(self.pattern)}")

    # ------------------------------------------------------------------
    @property
    def repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sub_quadratic(self) -> bool:
        """True if sequence cost is sub-quadratic (SSM/hybrid)."""
        kinds = {k for k, _ in self.pattern}
        return "mamba" in kinds

    def shapes(self) -> list[str]:
        """Applicable shape cells for this arch (assignment rules)."""
        out = ["train_4k", "prefill_32k"]
        if not self.is_encoder:
            out.append("decode_32k")
            if self.sub_quadratic:
                out.append("long_500k")
        return out

    def skipped_shapes(self) -> dict[str, str]:
        sk = {}
        if self.is_encoder:
            sk["decode_32k"] = "encoder-only: no decode step"
            sk["long_500k"] = "encoder-only: no decode step"
        elif not self.sub_quadratic:
            sk["long_500k"] = ("pure full-attention arch: 500k decode "
                               "needs sub-quadratic attention (skip per "
                               "assignment; noted in DESIGN.md)")
        return sk

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        period = len(self.pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=period * 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            moe_d_ff=32 if self.moe_num_experts else 0,
            moe_num_experts=min(self.moe_num_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_group=256,
            num_media_tokens=8 if self.num_media_tokens else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_groups=1,
            ssm_chunk=16,
            dtype="float32",
            remat="none",
        )
