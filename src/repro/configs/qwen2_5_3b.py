"""qwen2.5-3b [dense] — 36L d2048 16H (GQA kv=2) ff11008 vocab 151936,
QKV bias, tied embeddings [hf:Qwen/Qwen2.5-3B; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    rope_theta=1000000.0,
    qkv_bias=True,
    tie_embeddings=True,
    pattern=(("attn", "mlp"),),
)
