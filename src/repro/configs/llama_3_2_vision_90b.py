"""llama-3.2-vision-90b [vlm] — 100L d8192 64H (GQA kv=8) ff28672
vocab 128256; gated cross-attention image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision scaled per assignment; unverified].

The vision frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed patch embeddings as cross-attention media.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    # period 5: four self-attention layers, then one gated cross-attn
    pattern=(("attn", "mlp"),) * 4 + (("cross", "mlp"),),
    num_media_tokens=1024,   # stubbed patch embeddings per example
)
