"""llama4-scout-17b-a16e [moe] — 48L d5120 40H (GQA kv=8) ff8192
vocab 202048, MoE 16 experts top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Early fusion is stubbed to the text backbone per the assignment (the
modality frontend supplies embeddings upstream of this stack). Top-1
routing stresses overflow the hardest — a key stealing-policy cell.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500000.0,
    pattern=(("attn", "moe"),),
    moe_num_experts=16,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_shared_expert=True,
)
