"""qwen3-14b [dense] — 40L d5120 40H (GQA kv=8) ff17408 vocab 151936,
qk-norm [hf:Qwen/Qwen3-14B per assignment; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    rope_theta=1000000.0,
    qk_norm=True,
    pattern=(("attn", "mlp"),),
)
