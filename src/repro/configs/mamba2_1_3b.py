"""mamba2-1.3b [ssm] — 48L d2048, attention-free SSD blocks (no MLP),
vocab 50280, ssm_state=128 [arXiv:2405.21060; unverified].

Attention-free ⇒ the paper's *stealing* component is inapplicable (no
expert queues, no attention shards); topology-aware placement still
applies (DESIGN.md §Arch-applicability). Runs long_500k.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,          # unused (attention-free); head_dim set explicitly
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    pattern=(("mamba", "none"),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
)
