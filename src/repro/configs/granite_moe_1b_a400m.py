"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8) expert-ff 512
vocab 49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

The most representative cell for the paper technique: 32 experts top-8
stresses capacity overflow; locality-aware stealing is on by default.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    rope_theta=10000.0,
    tie_embeddings=True,
    pattern=(("attn", "moe"),),
    moe_num_experts=32,
    moe_top_k=8,
    moe_d_ff=512,
)
