"""hubert-xlarge [audio] — 48L d1280 16H (MHA kv=16) ff5120 vocab 504
(cluster targets), encoder-only [arXiv:2106.07447; unverified].

The conv waveform frontend is a STUB per the assignment: ``input_specs``
feeds precomputed frame embeddings (B, S, 1280). Encoder-only ⇒ no decode
shapes (decode_32k / long_500k skipped).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    is_encoder=True,
    embeds_input=True,
    pattern=(("attn", "mlp"),),
)
