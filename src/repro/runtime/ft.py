"""Fault tolerance & elasticity runtime.

Production posture for thousand-node fleets, exercised here on simulated
topologies (the same code paths drive real meshes — only the failure
*detector* differs):

  * **Heartbeats + straggler detection** — per-host step-time EWMA with a
    robust z-score; hosts slower than ``threshold×`` the fleet median for
    ``patience`` consecutive beats are flagged. Mitigation at the SPMD
    level = evict + elastic remesh (you cannot re-balance a lockstep
    collective around one slow chip; the paper's work-stealing analogue
    applies *within* the program via routing, and *between* programs via
    eviction).
  * **Elastic remesh** — on failure, shrink the device set to the largest
    power-of-two rectangle, re-run the paper's priority placement on the
    *surviving* topology (priorities explicitly support "some cores have
    already been allocated/lost" — §IV), rebuild the mesh, and restore
    the latest checkpoint under the new shardings.
  * **Supervisor loop** — checkpoint-every-k, automatic
    restore-and-continue; data pipeline is stateless so resume is exact.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import placement, topology as topo_mod

__all__ = ["HeartbeatMonitor", "plan_elastic_remesh", "Supervisor"]


class HeartbeatMonitor:
    """Step-time EWMA per host; robust straggler flagging."""

    def __init__(self, num_hosts: int, alpha: float = 0.2,
                 threshold: float = 1.5, patience: int = 3):
        self.num_hosts = num_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ewma = np.zeros(num_hosts)
        self.strikes = np.zeros(num_hosts, np.int64)
        self.beats = np.zeros(num_hosts, np.int64)

    def beat(self, host: int, step_time: float):
        if self.beats[host] == 0:
            self.ewma[host] = step_time
        else:
            self.ewma[host] = (self.alpha * step_time
                               + (1 - self.alpha) * self.ewma[host])
        self.beats[host] += 1
        med = float(np.median(self.ewma[self.beats > 0]))
        if med > 0 and self.ewma[host] > self.threshold * med:
            self.strikes[host] += 1
        else:
            self.strikes[host] = 0

    def stragglers(self) -> list[int]:
        return [h for h in range(self.num_hosts)
                if self.strikes[h] >= self.patience]

    def missing(self, timeout_beats: int = 2) -> list[int]:
        """Hosts that stopped reporting (crash detection)."""
        if self.beats.max(initial=0) == 0:
            return []
        return [h for h in range(self.num_hosts)
                if self.beats[h] < self.beats.max() - timeout_beats]


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    surviving: tuple[int, ...]       # physical device ids kept, in logical order
    mesh_shape: tuple[int, ...]
    dropped: tuple[int, ...]
    data_parallel_scale: float       # new global-batch scale vs old


def plan_elastic_remesh(topo: topo_mod.Topology,
                        failed: Sequence[int],
                        mesh_shape: tuple[int, ...],
                        model_axis_size: int) -> RemeshPlan:
    """Shrink-and-relayout after device failures.

    Keeps the model axis intact (weights shard over it — its size is a
    property of the checkpoint layout) and shrinks the data axis to the
    largest power of two that fits the survivors; then orders survivors
    with the paper's priority walk restricted to the surviving topology,
    so the rebuilt rings stay low-hop even around the hole.
    """
    n = topo.num_cores
    failed_set = set(int(f) for f in failed)
    survivors = [d for d in range(n) if d not in failed_set]
    old_data = int(np.prod(mesh_shape)) // model_axis_size
    new_data = 1
    while new_data * 2 * model_axis_size <= len(survivors) and \
            new_data * 2 <= old_data:
        new_data *= 2
    keep = new_data * model_axis_size
    sub = topo.restrict(survivors)
    # two-stage paper walk: compact blob of `keep` survivors, then a
    # ring-aware order within it so the rebuilt mesh's model rings stay
    # minimal-hop around the failure holes
    blob = placement.device_order_priority(sub, (len(survivors),))[:keep]
    sub2 = sub.restrict([int(b) for b in blob])
    inner = placement.device_order_priority(
        sub2, (keep // model_axis_size, model_axis_size))
    order = [int(blob[i]) for i in inner]
    chosen = tuple(int(survivors[i]) for i in order)
    extra_dropped = tuple(sorted(set(survivors)
                                 - set(chosen))) + tuple(sorted(failed_set))
    return RemeshPlan(
        surviving=chosen,
        mesh_shape=(new_data, model_axis_size),
        dropped=extra_dropped,
        data_parallel_scale=new_data / old_data,
    )


class Supervisor:
    """Checkpoint/restart + straggler-eviction training supervisor.

    The driver supplies callbacks, so the same supervisor runs the real
    multi-host loop and the simulated tests:
      run_step(step)  -> step_time_per_host: list[float]
      save(step)      -> persist state
      restore()       -> (step, state) from latest checkpoint
      remesh(plan)    -> rebuild mesh/shardings after failure
    """

    def __init__(self, num_hosts: int, checkpoint_every: int,
                 run_step: Callable[[int], Sequence[float]],
                 save: Callable[[int], None],
                 restore: Callable[[], int],
                 remesh: Callable[[RemeshPlan], None] | None = None,
                 topo: topo_mod.Topology | None = None,
                 mesh_shape: tuple[int, ...] | None = None,
                 model_axis_size: int = 1,
                 monitor: HeartbeatMonitor | None = None):
        self.monitor = monitor or HeartbeatMonitor(num_hosts)
        self.checkpoint_every = checkpoint_every
        self.run_step = run_step
        self.save = save
        self.restore = restore
        self.remesh = remesh
        self.topo = topo
        self.mesh_shape = mesh_shape
        self.model_axis_size = model_axis_size
        self.events: list[tuple[int, str]] = []
        self.evicted: set[int] = set()

    def run(self, start_step: int, num_steps: int,
            inject_failure: dict[int, list[int]] | None = None) -> int:
        """Run steps [start, start+num); returns the final step.

        inject_failure: {step: [host_ids]} — test hook that marks hosts
        failed *before* that step executes.
        """
        step = start_step
        end = start_step + num_steps
        pending_failures = dict(inject_failure or {})
        while step < end:
            # a failure fires once: the dead hosts are removed by the
            # remesh, so the replayed steps after restore don't re-fail
            failed = pending_failures.pop(step, [])
            if failed:
                self.events.append((step, f"failure hosts={failed}"))
                # roll back to last checkpoint, shrink, continue
                if self.remesh is not None and self.topo is not None:
                    plan = plan_elastic_remesh(
                        self.topo, failed, self.mesh_shape,
                        self.model_axis_size)
                    self.remesh(plan)
                    self.events.append(
                        (step, f"remesh {plan.mesh_shape} "
                               f"dropped={len(plan.dropped)}"))
                step = self.restore()
                self.events.append((step, "restored"))
                continue
            times = self.run_step(step)
            for h, t in enumerate(times):
                if h not in self.evicted:
                    self.monitor.beat(h, t)
            slow = [h for h in self.monitor.stragglers()
                    if h not in self.evicted]
            if slow:
                self.events.append((step, f"stragglers={slow}"))
                # eviction policy: treat persistent stragglers as failures
                if self.remesh is not None and self.topo is not None:
                    plan = plan_elastic_remesh(
                        self.topo, slow, self.mesh_shape,
                        self.model_axis_size)
                    self.remesh(plan)
                    self.events.append(
                        (step, f"remesh {plan.mesh_shape} evicted={slow}"))
                self.evicted.update(slow)
            step += 1
            if step % self.checkpoint_every == 0:
                self.save(step)
                self.events.append((step, "checkpoint"))
        return step
