from .ft import HeartbeatMonitor, RemeshPlan, Supervisor, plan_elastic_remesh
__all__ = ["HeartbeatMonitor", "Supervisor", "plan_elastic_remesh", "RemeshPlan"]
