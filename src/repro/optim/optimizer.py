"""Optimizer substrate: AdamW + schedules + grad accumulation +
int8 gradient compression with error feedback.

All states are pytrees shaped like the params, so the sharding rules
engine shards optimizer state exactly like the parameters (ZeRO-style:
params/м/v sharded over the data axis — GSPMD materializes gathers).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm", "accumulate_gradients",
           "compress_int8", "decompress_int8", "CompressionState",
           "compressed_gradients"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # memory mode for ≥100B models on 16 GB/chip: Adafactor-style
    # factored second moment (row/col stats) + bf16 first moment.
    factored: bool = False
    m_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * cos
    return cfg.lr_peak * warm * frac


def adamw_init(params: Params, cfg: AdamWConfig | None = None) -> dict:
    cfg = cfg or AdamWConfig()
    m_dt = jnp.dtype(cfg.m_dtype)

    def v_init(p):
        if cfg.factored and p.ndim >= 2:
            return dict(vr=jnp.zeros(p.shape[:-1], jnp.float32),
                        vc=jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                     jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    return dict(
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, m_dt), params),
        v=jax.tree.map(v_init, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Params):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale)
                        .astype(x.dtype), grads), g


def adamw_update(grads: Params, state: dict, params: Params,
                 cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = cosine_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        if isinstance(v, dict):
            # Adafactor-style factored second moment
            g2 = gf * gf + 1e-30
            vr = cfg.b2 * v["vr"] + (1 - cfg.b2) * g2.mean(-1)
            vc = cfg.b2 * v["vc"] + (1 - cfg.b2) * g2.mean(-2)
            vh = (vr[..., :, None] * vc[..., None, :]
                  / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30)) / b2c
            v_new = dict(vr=vr, vc=vc)
        else:
            v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
            vh = v_new / b2c
        mh = m_new / b1c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        pf = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim ≥ 2)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * pf
        return (pf - lr * step).astype(p.dtype), m_new.astype(m.dtype), v_new

    is_v_leaf = lambda x: isinstance(x, dict) and "vr" in x
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_v_leaf)[0]

    # Chain the big-leaf updates with optimization barriers so the
    # scheduler can't run every leaf's f32 transients concurrently —
    # otherwise peak temp memory scales with the whole parameter tree
    # instead of one leaf (elementwise updates gain nothing from overlap).
    big = 1 << 25  # 32M elements
    out = []
    prev_done = None
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if prev_done is not None and p.size >= big:
            p, prev_done = jax.lax.optimization_barrier((p, prev_done))
        res = upd(p, g, m, v)
        if p.size >= big:
            prev_done = res[0]
        out.append(res)
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, dict(m=new_m, v=new_v, count=count), \
        dict(lr=lr, grad_norm=gnorm)


def accumulate_gradients(loss_fn: Callable, params: Params, batch: dict,
                         num_microbatches: int,
                         acc_dtype=None):
    """Grad accumulation via lax.scan over microbatch slices.

    loss_fn(params, microbatch) -> (loss, metrics). The global batch's
    leading axis is split into ``num_microbatches`` slices; returns mean
    loss/grads. One traced microbatch keeps the HLO small and caps
    activation memory at (batch / n_micro).

    acc_dtype: dtype of the accumulation buffer (default f32). bf16
    halves the second gradient-sized buffer on ≥100B models; the per-
    microbatch gradients are still produced in their natural dtype and
    summed into the buffer (loss scale 1/n applied at the end).
    """
    if num_microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, grads, metrics
    acc_dtype = jnp.dtype(acc_dtype or jnp.float32)

    def slice_mb(i):
        def f(x):
            mb = x.shape[0] // num_microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
        return jax.tree.map(f, batch)

    def body(carry, i):
        loss_acc, grads_acc = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, slice_mb(i))
        grads_acc = jax.tree.map(
            lambda a, g: (a + g.astype(acc_dtype)).astype(acc_dtype),
            grads_acc, grads)
        return (loss_acc + loss, grads_acc), metrics

    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              params)
    (loss_sum, grads_sum), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_grads),
        jnp.arange(num_microbatches))
    n = float(num_microbatches)
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) / n), grads_sum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / n, grads, metrics


# ----------------------------------------------------------------------
# int8 gradient compression with error feedback (cross-pod reduction)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class CompressionState:
    """Per-leaf error-feedback residuals (pytree like params)."""
    residual: Params


def compress_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_gradients(grads: Params, comp: CompressionState | None):
    """Quantize grads to int8 with error feedback.

    The caller reduces the int8 payload across the slow (pod) axis —
    4× less DCI traffic than f32, 2× less than bf16 — then dequantizes.
    Error feedback carries the quantization residual into the next step,
    preserving convergence (1-bit-Adam-style analysis applies).

    Returns (dequantized_grads, new_comp_state) — in-graph simulation of
    the wire format so tests validate end-to-end numerics.
    """
    if comp is None:
        comp = CompressionState(residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(comp.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in outs]), \
        CompressionState(tdef.unflatten([o[1] for o in outs]))
