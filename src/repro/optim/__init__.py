from .optimizer import (AdamWConfig, CompressionState, accumulate_gradients,
                        adamw_init, adamw_update, clip_by_global_norm,
                        compressed_gradients, cosine_schedule, global_norm)
__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm", "accumulate_gradients",
           "compressed_gradients", "CompressionState"]
