"""Deterministic, stateless-resume synthetic token pipeline.

Design goals for thousand-node training:
  * **Stateless indexing** — ``batch_at(step)`` is a pure function of
    (seed, step), so restart-after-failure resumes mid-epoch exactly,
    with no iterator state in the checkpoint beyond the step counter.
  * **Per-host sharding** — each host materializes only its slice of the
    global batch (``host_batch_at``); slices concatenate to the global
    batch in host-id order, independent of host count (elastic rescale
    keeps the data order).
  * **Packing** — documents of Zipf-ish lengths packed into fixed
    ``seq_len`` rows with EOS separators and −100 label masking across
    document boundaries, mimicking a production LM mixture.
  * **Prefetch** — a double-buffering background thread hides host-side
    generation behind device compute.

The generator is a counter-based hash (SplitMix64-style) — no sequential
RNG state anywhere.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["PipelineConfig", "TokenPipeline", "Prefetcher"]

_MASK64 = (1 << 64) - 1


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized SplitMix64 over uint64 counters."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(_MASK64)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(_MASK64)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(_MASK64)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512
    pack: bool = True
    # modality stubs
    embeds_dim: int = 0         # >0 → emit frame embeddings (audio)
    media_tokens: int = 0       # >0 → emit patch embeddings (vlm)
    d_model: int = 0


class TokenPipeline:
    """Synthetic LM data with next-token labels."""

    def __init__(self, cfg: PipelineConfig):
        if cfg.vocab_size < 2:
            raise ValueError("vocab_size must be ≥ 2")
        self.cfg = cfg

    # -- core --------------------------------------------------------
    @property
    def _bigram(self) -> np.ndarray:
        """Deterministic vocabulary permutation — the learnable structure.

        The stream is a Markov chain: with prob 3/4 the next token is
        ``perm[current]``, else uniform noise. A model that learns the
        256…152k-entry bigram map reaches CE ≈ H(noise) ≪ ln(V); pure
        hash noise would be unlearnable and make convergence tests
        meaningless."""
        if not hasattr(self, "_bigram_cache"):
            rng = np.random.RandomState(self.cfg.seed ^ 0x5bd1e995)
            self._bigram_cache = rng.permutation(self.cfg.vocab_size)
        return self._bigram_cache

    def _tokens(self, step: int, rows: np.ndarray) -> np.ndarray:
        """(len(rows), seq_len+1) tokens for global row indices."""
        c = self.cfg
        S = c.seq_len + 1
        ctr = ((c.seed << 32) ^ step) & _MASK64
        ctr_mix = np.uint64((ctr * 0x9E3779B97F4A7C15) & _MASK64)
        base = (rows.astype(np.uint64)[:, None] * np.uint64(1 << 20)
                + np.arange(S, dtype=np.uint64)[None, :])
        h = _splitmix64(base ^ ctr_mix)
        noise = (h % np.uint64(c.vocab_size - 1)).astype(np.int64) + 1
        use_noise = ((h >> np.uint64(40)) % np.uint64(4)) == 0  # 25%
        perm = self._bigram
        toks = np.empty_like(noise)
        toks[:, 0] = noise[:, 0]
        for t in range(1, S):  # stateless: everything derives from (seed, step)
            nxt = perm[toks[:, t - 1]]
            toks[:, t] = np.where(use_noise[:, t], noise[:, t], nxt)
        if not c.pack:
            return toks
        # deterministic doc boundaries: EOS roughly every mean_doc_len
        hb = _splitmix64(base ^ np.uint64(0xD1B54A32D192ED03) ^ ctr_mix)
        is_eos = (hb % np.uint64(c.mean_doc_len)) == 0
        toks[is_eos] = c.eos_id
        return toks

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rows = np.arange(self.cfg.global_batch, dtype=np.int64)
        return self._assemble(step, rows)

    def host_batch_at(self, step: int, host_id: int,
                      num_hosts: int) -> dict[str, np.ndarray]:
        gb = self.cfg.global_batch
        if gb % num_hosts:
            raise ValueError(f"global_batch {gb} % hosts {num_hosts} != 0")
        per = gb // num_hosts
        rows = np.arange(host_id * per, (host_id + 1) * per, dtype=np.int64)
        return self._assemble(step, rows)

    def _assemble(self, step: int, rows: np.ndarray) -> dict[str, np.ndarray]:
        c = self.cfg
        toks = self._tokens(step, rows)
        batch: dict[str, np.ndarray] = {}
        labels = toks[:, 1:].copy()
        if c.pack:
            # don't predict across document boundaries
            labels[toks[:, 1:] == c.eos_id] = -100
        batch["labels"] = labels.astype(np.int32)
        if c.embeds_dim:
            # audio stub: frame embeddings instead of tokens
            ctr = np.uint64(c.seed * 1315423911 + step)
            h = _splitmix64(
                (rows.astype(np.uint64)[:, None, None] * np.uint64(1 << 40))
                + (np.arange(c.seq_len, dtype=np.uint64)[None, :, None]
                   << np.uint64(16))
                + np.arange(c.embeds_dim, dtype=np.uint64)[None, None, :]
                ^ ctr)
            batch["embeds"] = ((h >> np.uint64(40)).astype(np.float32)
                               / (1 << 24) - 0.5)
        else:
            batch["tokens"] = toks[:, :-1].astype(np.int32)
        if c.media_tokens:
            ctr = np.uint64(c.seed * 2654435761 + step)
            h = _splitmix64(
                (rows.astype(np.uint64)[:, None, None] * np.uint64(1 << 40))
                + (np.arange(c.media_tokens, dtype=np.uint64)[None, :, None]
                   << np.uint64(16))
                + np.arange(c.d_model, dtype=np.uint64)[None, None, :]
                ^ ctr)
            batch["media"] = ((h >> np.uint64(40)).astype(np.float32)
                              / (1 << 24) - 0.5)
        return batch

    def iter_from(self, step: int) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Double-buffering background producer over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
            self._q.put(None)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def pipeline_for_arch(arch_cfg, shape, seed: int = 0) -> TokenPipeline:
    """Pipeline matching an (ArchConfig, ShapeSpec) cell."""
    return TokenPipeline(PipelineConfig(
        vocab_size=arch_cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        embeds_dim=arch_cfg.d_model if arch_cfg.embeds_input else 0,
        media_tokens=arch_cfg.num_media_tokens,
        d_model=arch_cfg.d_model,
    ))
