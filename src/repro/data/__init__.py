from .pipeline import PipelineConfig, Prefetcher, TokenPipeline, pipeline_for_arch
__all__ = ["PipelineConfig", "TokenPipeline", "Prefetcher", "pipeline_for_arch"]
