"""Cross-process compile-cache smoke: cold build, then a warm hit.

The persistent compile cache's whole point is *cross-process* reuse, so
this harness measures it the only honest way: fresh interpreter per
measurement.

* **child mode** (``--child``): one process-lifecycle sample. Builds the
  workload via ``bots.make``, runs one ``Machine.run`` under the paper
  binding, and prints a JSON record — per-phase timings, the result
  fields, the cache hit/miss counters, and whether *this* process
  invoked the C compiler.
* **driver mode** (default): points ``REPRO_SIM_CACHE`` at a fresh
  temp directory, runs the child twice, and asserts the contract CI
  pins: the second process hits every artifact class it consults (no
  table rebuild, no serial walk, no ``cc`` invocation) and returns
  bit-identical results to the cold one. ``--engine py|c`` crosses the
  check over both engines (mmap'd tables must be transparent to each).

Used by CI (cache-smoke job) and by ``bench_sim`` to record the
``paper+cachecold`` / ``paper+cachehit`` rows.

    PYTHONPATH=src python -m benchmarks.cache_smoke [--engine c|py]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time


def run_child(workload: str, scale: str, scheduler: str, threads: int,
              seed: int) -> dict:
    """One fresh-process sample (see module docstring, child mode)."""
    t_start = time.perf_counter()
    from repro.core import topology
    from repro.core.sim import Machine, bots, get_cache
    from repro.core.sim import _csim
    from repro.core.sim.runtime import _select_engine
    import_s = time.perf_counter() - t_start

    t0 = time.perf_counter()
    wl = bots.make(workload, scale)
    make_s = time.perf_counter() - t0
    machine = Machine(topology.sunfire_x4600())
    ctx = machine.context(threads, binding="paper")
    t0 = time.perf_counter()
    r = machine.run(wl, scheduler, seed=seed, context=ctx)
    run_s = time.perf_counter() - t0

    cache = get_cache()
    return dict(
        workload=workload, scale=scale, scheduler=scheduler,
        threads=threads, seed=seed,
        engine=_select_engine(), tasks=int(wl.table.n),
        import_s=round(import_s, 6), make_s=round(make_s, 6),
        run_s=round(run_s, 6),
        first_result_s=round(make_s + run_s, 6),
        makespan=r.makespan, speedup=r.speedup, steals=r.steals,
        remote_work_fraction=r.remote_work_fraction,
        compiled_c_kernel=_csim.compiled_this_process,
        cache=None if cache is None else cache.stats())


def spawn_child(cache_root: str, engine: str, workload: str, scale: str,
                scheduler: str, threads: int, seed: int) -> dict:
    env = dict(os.environ, REPRO_SIM_CACHE=cache_root,
               REPRO_SIM_ENGINE=engine)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.cache_smoke", "--child",
         "--workload", workload, "--scale", scale,
         "--scheduler", scheduler, "--threads", str(threads),
         "--seed", str(seed)],
        env=env, capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"cache-smoke child failed (rc={proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.splitlines()[-1])


def smoke(engine: str, workload: str = "fft", scale: str = "paper",
          scheduler: str = "wf", threads: int = 16, seed: int = 0,
          verbose: bool = True) -> "tuple[dict, dict]":
    """Cold + warm child under a fresh cache root; asserts the contract.

    Returns ``(cold, warm)`` child records for callers (bench_sim) that
    want the timings.
    """
    with tempfile.TemporaryDirectory(prefix="repro-sim-smoke-") as root:
        cold = spawn_child(root, engine, workload, scale, scheduler,
                          threads, seed)
        warm = spawn_child(root, engine, workload, scale, scheduler,
                          threads, seed)

    assert cold["cache"] is not None, "cache unexpectedly disabled"
    assert cold["cache"]["hits"] == {}, \
        f"cold process hit a fresh cache: {cold['cache']}"
    misses = cold["cache"]["misses"]
    assert misses.get("tables") and misses.get("serial"), \
        f"cold process consulted no table/serial artifacts: {misses}"

    hits = warm["cache"]["hits"]
    assert warm["cache"]["misses"] == {}, \
        f"warm process missed: {warm['cache']}"
    assert hits.get("tables") and hits.get("serial"), \
        f"warm process did not hit table+serial artifacts: {hits}"
    if engine == "c":
        assert cold["compiled_c_kernel"] or warm["engine"] != "c", \
            "cold process reused a kernel it should have had to build"
        assert not warm["compiled_c_kernel"], \
            "warm process invoked the C compiler"
    for rec in (cold, warm):
        assert rec["engine"] == engine, \
            f"requested engine {engine!r}, got {rec['engine']!r}"

    # bit-identical results: cached artifacts must be transparent
    for field in ("makespan", "speedup", "steals",
                  "remote_work_fraction", "tasks"):
        assert cold[field] == warm[field], \
            f"{field}: cold={cold[field]!r} != warm={warm[field]!r}"

    if verbose:
        print(f"[{engine}] cold: make={cold['make_s']:.3f}s "
              f"run={cold['run_s']:.3f}s "
              f"(compiled_cc={cold['compiled_c_kernel']})")
        print(f"[{engine}] warm: make={warm['make_s']:.3f}s "
              f"run={warm['run_s']:.3f}s "
              f"first_result={warm['first_result_s']:.3f}s "
              f"hits={hits}")
        print(f"[{engine}] results identical "
              f"(makespan={cold['makespan']!r}) — PASS")
    return cold, warm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true",
                    help="run one in-process sample and print JSON")
    ap.add_argument("--engine", default="c", choices=("c", "py"),
                    help="driver mode: engine to cross the smoke over")
    ap.add_argument("--workload", default="fft")
    ap.add_argument("--scale", default="paper")
    ap.add_argument("--scheduler", default="wf")
    ap.add_argument("--threads", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.child:
        print(json.dumps(run_child(args.workload, args.scale,
                                   args.scheduler, args.threads,
                                   args.seed)))
        return
    smoke(args.engine, args.workload, args.scale, args.scheduler,
          args.threads, args.seed)


if __name__ == "__main__":
    main()
