"""Generate the data-driven sections of EXPERIMENTS.md (§Dry-run tables,
§Roofline table, §Sim-perf table) from artifacts/dryrun +
artifacts/roofline.json + BENCH_sim.json.

    PYTHONPATH=src python -m benchmarks.report > artifacts/report.md
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks import roofline as rf

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load_cells():
    cells = []
    for p in sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun", "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def dryrun_table(cells) -> str:
    out = ["| arch | shape | mesh | status | mem/dev GiB | flops/dev | "
           "bytes/dev | collectives (count: wire GiB, cross-pod GiB) | "
           "compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("status") == "skipped":
            out.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                       f"skip: {c['reason'][:60]} | | | | | |")
            continue
        if c.get("status") != "ok":
            out.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                       f"ERROR | | | | | |")
            continue
        mem = ((c["memory"]["argument_bytes"] or 0)
               + (c["memory"]["temp_bytes"] or 0)) / 2**30
        colls = c.get("collectives") or {}
        nops = sum(v["count"] for v in colls.values())
        wire = sum(v.get("wire_bytes", v["bytes"])
                   for v in colls.values()) / 2**30
        xwire = sum(v.get("cross_pod_wire_bytes", 0)
                    for v in colls.values()) / 2**30
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | ok | "
            f"{mem:.2f} | {c['cost']['flops_per_device']:.3e} | "
            f"{(c['cost']['bytes_accessed_per_device'] or 0):.3e} | "
            f"{nops}: {wire:.3f}, {xwire:.3f} | {c['compile_s']} |")
    return "\n".join(out)


def collective_breakdown(cells) -> str:
    """Per-kind collective summary for the multi-pod mesh (train cells)."""
    out = ["| arch.shape | kind | count | wire GiB/dev | cross-pod GiB |",
           "|---|---|---|---|---|"]
    for c in cells:
        if c.get("status") != "ok" or c["mesh"] != "multi":
            continue
        for kind, v in (c.get("collectives") or {}).items():
            out.append(
                f"| {c['arch']}.{c['shape']} | {kind} | {v['count']} | "
                f"{v.get('wire_bytes', v['bytes'])/2**30:.3f} | "
                f"{v.get('cross_pod_wire_bytes', 0)/2**30:.3f} |")
    return "\n".join(out)


def _fmt(value, spec: str = "") -> str:
    """Format one metric cell; null metrics (e.g. a batch row's
    undefined speedup) render as an em-dash instead of a fake number."""
    if value is None:
        return "—"
    return format(value, spec)


def sim_bench_table(path: "str | None" = None) -> str:
    """BENCH_sim.json results as markdown (null-safe, see _fmt)."""
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_sim.json")
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return "(no BENCH_sim.json)"
    out = ["| workload | scale | scheduler | engine | build_s | cold_s | "
           "warm_s | tasks/s | speedup | steals | reclaimed | reexec | "
           "fault_lost |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in doc.get("results", []):
        out.append(
            f"| {r['workload']} | {r['scale']} | {r['scheduler']} | "
            f"{r['engine']} | {_fmt(r.get('build_s'), '.3f')} | "
            f"{_fmt(r.get('cold_s'), '.4f')} | "
            f"{_fmt(r.get('warm_s'), '.4f')} | "
            f"{_fmt(r.get('tasks_per_s'), '.0f')} | "
            f"{_fmt(r.get('speedup'))} | {_fmt(r.get('steals'))} | "
            f"{_fmt(r.get('reclaimed'))} | {_fmt(r.get('reexec'))} | "
            f"{_fmt(r.get('fault_lost'), '.2f')} |")
    return "\n".join(out)


def main():
    cells = load_cells()
    rows = rf.analyze()
    n_ok = sum(1 for c in cells if c.get("status") == "ok")
    n_skip = sum(1 for c in cells if c.get("status") == "skipped")
    print("## §Dry-run (generated)\n")
    print(f"{n_ok} cells compiled, {n_skip} documented skips, "
          f"{len(cells) - n_ok - n_skip} errors "
          f"(meshes: 16×16 = 256 chips, 2×16×16 = 512 chips).\n")
    print(dryrun_table(cells))
    print("\n### Multi-pod collective schedules (per device)\n")
    print(collective_breakdown(cells))
    print("\n## §Roofline (generated)\n")
    print(rf.markdown_table(rows))
    print("\n## §Sim perf (generated)\n")
    print(sim_bench_table())


if __name__ == "__main__":
    main()
