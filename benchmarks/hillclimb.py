"""§Perf hillclimbs: hypothesis → change → re-lower → measure.

Three roofline cells (see EXPERIMENTS.md §Perf for selection rationale)
plus a simulator *strategy* hillclimb (``--cell 4``): the paper's own
progression — baseline Nanos → +priority binding → +master-node spill →
+NUMA-aware stealing — expressed as one-context-knob-at-a-time
:class:`~repro.core.sim.Machine` variants, so each step isolates one
declarative change exactly like the roofline cells isolate one config
override. Results land in artifacts/hillclimb/ and the comparison table
is printed for the §Perf log.

    PYTHONPATH=src python -m benchmarks.hillclimb [--cell N]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import cell_roofline

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "hillclimb")


def _run(arch, shape, mesh, variant=None, **kw):
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, mesh, skip_existing=True, variant=variant,
                   out_dir=ART, **kw)
    r = cell_roofline(rec)
    r["variant"] = variant or "baseline"
    return r


def _show(rows):
    print(f"{'variant':28s} {'compute_s':>10} {'memory_s':>10} "
          f"{'coll_s':>10} {'dominant':>12} {'mem GiB':>8} {'frac':>7}")
    for r in rows:
        print(f"{r['variant']:28s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
              f"{r['dominant'][:-2]:>12} {r['memory_gib']:8.2f} "
              f"{r['roofline_fraction']:7.3f}")
    return rows


def cell_granite():
    """granite-moe train_4k multi — the paper-technique cell.

    Baseline = paper-faithful (DFWSPT stealing on). Variants probe the
    dominant term with the technique held fixed, plus the
    paper-ablation (stealing off) for the §Repro delta.
    """
    a, s, m = "granite-moe-1b-a400m", "train_4k", "multi"
    rows = [_run(a, s, m)]
    # paper-ablation: vanilla GShard drops instead of locality stealing
    rows.append(_run(a, s, m, "nosteal",
                     cfg_overrides=dict(moe_steal_attempts=0)))
    # H1: grad sync dominates collectives → bf16 accumulation halves it
    rows.append(_run(a, s, m, "bf16grads",
                     opt_overrides=dict(factored=True,
                                        m_dtype="bfloat16")))
    # H2: smaller routing groups shrink dispatch one-hots (memory) at the
    # cost of more, smaller expert matmuls
    rows.append(_run(a, s, m, "group1024",
                     cfg_overrides=dict(moe_group=1024)))
    # H3: fewer microbatches → less recompute per step (compute term)
    rows.append(_run(a, s, m, "micro2", micro_override=2))
    # H4 (beyond-paper): d_model=1024 over 16-way TP is slivers — drop TP
    # entirely, keep EP on "model" + FSDP over both axes. Kills the
    # Megatron all-reduces that dominate this cell.
    rows.append(_run(a, s, m, "ep-only",
                     cfg_overrides=dict(sharding_profile="ep_only")))
    return _show(rows)


def cell_commandr():
    """command-r-35b decode_32k single — memory-bound decode.

    Baseline doubles the KV cache via kv_repeat (TP>kv). Variant:
    sequence-sharded cache (flash-decoding layout) — no replication.
    """
    a, s, m = "command-r-35b", "decode_32k", "single"
    rows = [_run(a, s, m)]
    rows.append(_run(a, s, m, "seqshard",
                     cfg_overrides=dict(
                         kv_repeat=1,
                         attn_kv_spec=(("data",), "model", None, None))))
    rows.append(_run(a, s, m, "seqshard-f32stats",
                     cfg_overrides=dict(
                         kv_repeat=1,
                         attn_chunk_threshold=1 << 30,
                         attn_kv_spec=(("data",), "model", None, None))))
    return _show(rows)


def cell_jamba():
    """jamba-398B train_4k single — biggest model, smaller mesh."""
    a, s, m = "jamba-1.5-large-398b", "train_4k", "single"
    rows = [_run(a, s, m)]
    # H1: selective remat (keep matmul outputs) trades memory for flops
    rows.append(_run(a, s, m, "remat-dots",
                     cfg_overrides=dict(remat="dots")))
    # H2: fewer microbatches → fewer recompute passes, more activation mem
    rows.append(_run(a, s, m, "micro8", micro_override=8))
    # H3: larger SSD chunks → bigger MXU matmuls, fewer scan steps
    rows.append(_run(a, s, m, "ssdchunk256",
                     cfg_overrides=dict(ssm_chunk=256)))
    # H4: keep shrinking the regather traffic (micro8 confirmed H2)
    rows.append(_run(a, s, m, "micro4", micro_override=4))
    return _show(rows)


def cell_sim():
    """NUMA-strategy hillclimb on the NANOS simulator (fft medium @ 16).

    Each variant flips exactly one execution-context knob relative to
    the previous row — the paper's §IV→§V→§VI progression, plus the
    policy layer's hierarchical-stealing step beyond it.

    Every cell is evaluated through the persistent result store
    (artifacts/hillclimb/sim_cells.jsonl): repeated searches over the
    same (topology, workload) replay already-scored variants from the
    journal instead of re-simulating them — the substrate the ROADMAP
    auto-tuner's search loop builds on.
    """
    from repro.core import topology
    from repro.core.sim import Machine, ResultStore, bots

    m = Machine(topology.sunfire_x4600())
    wl = bots.fft(n=1 << 15, cutoff=4)
    serial = m.serial_time(wl, placement="spill:2@0")
    os.makedirs(ART, exist_ok=True)
    store = ResultStore(os.path.join(ART, "sim_cells.jsonl"))
    base = dict(placement="spill:2@0", runtime_data=0, migration_rate=0.15)
    variants = [
        ("baseline-nanos", "wf", dict(binding="linear", **base)),
        ("+priority-binding", "wf", dict(binding="paper", **base)),
        ("+pin-threads", "wf",
         dict(binding="paper", placement="spill:2@0", runtime_data=0)),
        ("+local-runtime", "wf",
         dict(binding="paper", placement="spill:2@0")),
        ("+master-spill", "wf", dict(binding="paper", placement="spill:2")),
        ("+dfwsrpt-stealing", "dfwsrpt",
         dict(binding="paper", placement="spill:2")),
        ("hier-stealing", "dfwshier",
         dict(binding="paper", placement="spill:2")),
    ]
    rows = []
    print(f"{'variant':22s} {'sched':10s} {'speedup':>8} {'remote%':>8} "
          f"{'steals':>8} {'queue_wait':>10}")
    for label, sched, ctx_kw in variants:
        r = m.run(wl, sched, seed=0, threads=16, serial_reference=serial,
                  store=store, **ctx_kw)
        rows.append(dict(variant=label, scheduler=sched,
                         speedup=round(r.speedup, 4),
                         remote_work_fraction=round(r.remote_work_fraction,
                                                    4),
                         steals=r.steals,
                         queue_wait=round(r.queue_wait, 2)))
        print(f"{label:22s} {sched:10s} {r.speedup:8.2f} "
              f"{r.remote_work_fraction * 100:8.2f} {r.steals:8d} "
              f"{r.queue_wait:10.1f}")
    print(f"[store] {store!r}")
    if m.compile_cache is not None:
        print(f"[compile-cache] {m.compile_cache!r}")
    store.close()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=0,
                    help="1=granite 2=command-r 3=jamba 4=sim-strategy; "
                         "0=all")
    args = ap.parse_args()
    os.makedirs(ART, exist_ok=True)
    out = {}
    if args.cell in (0, 1):
        print("== granite-moe-1b-a400m × train_4k × multi ==")
        out["granite"] = cell_granite()
    if args.cell in (0, 2):
        print("== command-r-35b × decode_32k × single ==")
        out["commandr"] = cell_commandr()
    if args.cell in (0, 3):
        print("== jamba-1.5-large-398b × train_4k × single ==")
        out["jamba"] = cell_jamba()
    if args.cell in (0, 4):
        print("== NANOS sim × fft-medium × NUMA strategy ==")
        out["sim"] = cell_sim()
    with open(os.path.join(ART, "summary.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
