"""§Perf hillclimbs: hypothesis → change → re-lower → measure.

Three cells (see EXPERIMENTS.md §Perf for selection rationale). Each
variant re-compiles the cell with one change and records the roofline
terms; results land in artifacts/hillclimb/ and the comparison table is
printed for the §Perf log.

    PYTHONPATH=src python -m benchmarks.hillclimb [--cell N]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import cell_roofline

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "hillclimb")


def _run(arch, shape, mesh, variant=None, **kw):
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, mesh, skip_existing=True, variant=variant,
                   out_dir=ART, **kw)
    r = cell_roofline(rec)
    r["variant"] = variant or "baseline"
    return r


def _show(rows):
    print(f"{'variant':28s} {'compute_s':>10} {'memory_s':>10} "
          f"{'coll_s':>10} {'dominant':>12} {'mem GiB':>8} {'frac':>7}")
    for r in rows:
        print(f"{r['variant']:28s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
              f"{r['dominant'][:-2]:>12} {r['memory_gib']:8.2f} "
              f"{r['roofline_fraction']:7.3f}")
    return rows


def cell_granite():
    """granite-moe train_4k multi — the paper-technique cell.

    Baseline = paper-faithful (DFWSPT stealing on). Variants probe the
    dominant term with the technique held fixed, plus the
    paper-ablation (stealing off) for the §Repro delta.
    """
    a, s, m = "granite-moe-1b-a400m", "train_4k", "multi"
    rows = [_run(a, s, m)]
    # paper-ablation: vanilla GShard drops instead of locality stealing
    rows.append(_run(a, s, m, "nosteal",
                     cfg_overrides=dict(moe_steal_attempts=0)))
    # H1: grad sync dominates collectives → bf16 accumulation halves it
    rows.append(_run(a, s, m, "bf16grads",
                     opt_overrides=dict(factored=True,
                                        m_dtype="bfloat16")))
    # H2: smaller routing groups shrink dispatch one-hots (memory) at the
    # cost of more, smaller expert matmuls
    rows.append(_run(a, s, m, "group1024",
                     cfg_overrides=dict(moe_group=1024)))
    # H3: fewer microbatches → less recompute per step (compute term)
    rows.append(_run(a, s, m, "micro2", micro_override=2))
    # H4 (beyond-paper): d_model=1024 over 16-way TP is slivers — drop TP
    # entirely, keep EP on "model" + FSDP over both axes. Kills the
    # Megatron all-reduces that dominate this cell.
    rows.append(_run(a, s, m, "ep-only",
                     cfg_overrides=dict(sharding_profile="ep_only")))
    return _show(rows)


def cell_commandr():
    """command-r-35b decode_32k single — memory-bound decode.

    Baseline doubles the KV cache via kv_repeat (TP>kv). Variant:
    sequence-sharded cache (flash-decoding layout) — no replication.
    """
    a, s, m = "command-r-35b", "decode_32k", "single"
    rows = [_run(a, s, m)]
    rows.append(_run(a, s, m, "seqshard",
                     cfg_overrides=dict(
                         kv_repeat=1,
                         attn_kv_spec=(("data",), "model", None, None))))
    rows.append(_run(a, s, m, "seqshard-f32stats",
                     cfg_overrides=dict(
                         kv_repeat=1,
                         attn_chunk_threshold=1 << 30,
                         attn_kv_spec=(("data",), "model", None, None))))
    return _show(rows)


def cell_jamba():
    """jamba-398B train_4k single — biggest model, smaller mesh."""
    a, s, m = "jamba-1.5-large-398b", "train_4k", "single"
    rows = [_run(a, s, m)]
    # H1: selective remat (keep matmul outputs) trades memory for flops
    rows.append(_run(a, s, m, "remat-dots",
                     cfg_overrides=dict(remat="dots")))
    # H2: fewer microbatches → fewer recompute passes, more activation mem
    rows.append(_run(a, s, m, "micro8", micro_override=8))
    # H3: larger SSD chunks → bigger MXU matmuls, fewer scan steps
    rows.append(_run(a, s, m, "ssdchunk256",
                     cfg_overrides=dict(ssm_chunk=256)))
    # H4: keep shrinking the regather traffic (micro8 confirmed H2)
    rows.append(_run(a, s, m, "micro4", micro_override=4))
    return _show(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=0,
                    help="1=granite 2=command-r 3=jamba; 0=all")
    args = ap.parse_args()
    os.makedirs(ART, exist_ok=True)
    out = {}
    if args.cell in (0, 1):
        print("== granite-moe-1b-a400m × train_4k × multi ==")
        out["granite"] = cell_granite()
    if args.cell in (0, 2):
        print("== command-r-35b × decode_32k × single ==")
        out["commandr"] = cell_commandr()
    if args.cell in (0, 3):
        print("== jamba-1.5-large-398b × train_4k × single ==")
        out["jamba"] = cell_jamba()
    with open(os.path.join(ART, "summary.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
