"""Roofline analysis (deliverable (g)): three terms per dry-run cell.

Sources:
  * ``compiled.cost_analysis()`` / parsed HLO collectives from
    ``artifacts/dryrun/*.json``. XLA counts every loop *body once*
    (verified: a scanned matmul reports 1× its body flops regardless of
    trip count), and our steps nest up to three loops (microbatch scan ×
    layer scan × chunk scans), so raw HLO numbers are per-body.
  * closed-form per-cell totals derived from the architecture configs —
    every matmul in the model is known — give the step totals. The raw
    per-body HLO numbers are kept in the artifacts as cross-checks, and
    the collective *schedule* (which kinds, which axes, cross-pod split)
    comes from the HLO.

Hardware: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
50 GB/s/link ICI, 6.25 GB/s/chip DCI (cross-pod).

    compute_s    = total_flops_per_chip / 197e12
    memory_s     = hbm_bytes_per_chip / 819e9
    collective_s = ici_bytes / 50e9 + dci_bytes / 6.25e9
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCI_BW = 6.25e9

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


# ----------------------------------------------------------------------
# analytic per-cell model
# ----------------------------------------------------------------------

def _mesh_dims(mesh_kind: str):
    if mesh_kind == "multi":
        return dict(devices=512, dp=32, tp=16, pods=2)
    return dict(devices=256, dp=16, tp=16, pods=1)


def analytic_terms(arch: str, shape_name: str, mesh_kind: str,
                   micro: int, cfg_overrides: dict | None = None,
                   grad_bytes: float = 4.0) -> dict:
    """Closed-form flops / HBM bytes / collective bytes per chip per step."""
    import dataclasses as _dc

    from repro import configs
    from repro.models import model as model_lib

    cfg = configs.get(arch)
    overridden = set()
    if cfg_overrides:
        ov = {k: (tuple(v) if isinstance(v, list) else v)
              for k, v in cfg_overrides.items()
              if hasattr(cfg, k)}
        overridden = set(ov)
        cfg = _dc.replace(cfg, **ov)
    shape = configs.SHAPES[shape_name]
    m = _mesh_dims(mesh_kind)
    dev, dp, tp, pods = m["devices"], m["dp"], m["tp"], m["pods"]

    N_total = model_lib.param_count(cfg)
    N_active = model_lib.active_param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    tokens = B * S if kind != "decode" else B
    tokens_dev = tokens / dp if kind != "decode" else tokens / min(dp, B)

    L_attn = cfg.repeats * sum(1 for k, _ in cfg.pattern if k == "attn")
    L_cross = cfg.repeats * sum(1 for k, _ in cfg.pattern if k == "cross")
    L_mamba = cfg.repeats * sum(1 for k, _ in cfg.pattern if k == "mamba")
    d_attn = cfg.num_heads * cfg.head_dim

    # ---- FLOPs ------------------------------------------------------
    if kind == "train":
        remat = 1.5 if len(cfg.pattern) > 1 else 4.0 / 3.0  # nested remat
        flops = 6.0 * N_active * tokens * remat
        # causal attention: fwd 2·S²·d (qk+pv halved by causality), ×3 bwd+remat
        flops += 3.0 * 2.0 * B * S * S * d_attn * L_attn
        flops += 3.0 * 4.0 * B * S * cfg.num_media_tokens * d_attn * L_cross
    elif kind == "prefill":
        flops = 2.0 * N_active * tokens
        flops += 2.0 * B * S * S * d_attn * L_attn
        flops += 4.0 * B * S * cfg.num_media_tokens * d_attn * L_cross
    else:  # decode: one token against an S-long cache / SSM state
        flops = 2.0 * N_active * B
        flops += 4.0 * B * S * d_attn * L_attn
        if L_mamba:
            d_inner = cfg.ssm_expand * cfg.d_model
            H = d_inner // cfg.ssm_head_dim
            flops += 4.0 * B * H * cfg.ssm_state * cfg.ssm_head_dim * L_mamba
    flops_dev = flops / dev

    # ---- HBM bytes --------------------------------------------------
    pb = 2.0 * N_total  # bf16 param bytes (global)
    if kind == "train":
        # weights: fwd + remat + bwd reads; grads f32 RW; m RW; v RW
        w_traffic = 3 * pb
        g_traffic = 2 * 4.0 * N_total
        m_bytes = 2.0 * N_total if _factored(arch) else 4.0 * N_total
        v_bytes = 0.1 * N_total if _factored(arch) else 4.0 * N_total
        o_traffic = 2 * (m_bytes + v_bytes) + 2 * pb  # states RW + param RW
        act = 16.0 * tokens * cfg.d_model * 2.0       # streamed activations
        bytes_total = w_traffic + g_traffic + o_traffic + act
        bytes_dev = bytes_total / dev
    elif kind == "prefill":
        act = 8.0 * tokens * cfg.d_model * 2.0
        kv = 2.0 * tokens * cfg.num_kv_heads * cfg.kv_repeat \
            * cfg.head_dim * 2.0 * L_attn
        bytes_dev = (pb + act + kv) / dev
    else:
        # decode reads all (active) weights once + the whole KV cache
        kv = 2.0 * B * S * cfg.num_kv_heads * cfg.head_dim * 2.0 * L_attn
        kv *= _kv_rep(cfg, tp, overridden)
        ssm = 0.0
        if L_mamba:
            d_inner = cfg.ssm_expand * cfg.d_model
            H = d_inner // cfg.ssm_head_dim
            ssm = 4.0 * B * H * cfg.ssm_state * cfg.ssm_head_dim * L_mamba
        bytes_dev = (2.0 * N_active * _moe_read_frac(cfg) + kv + ssm) / dev

    # ---- collective bytes -------------------------------------------
    ici = dci = 0.0
    D = cfg.d_model
    if kind == "train":
        # ZeRO-3 regather per microbatch (fwd + bwd) over the data axis
        gather = 2.0 * micro * (pb / tp) * (dp - 1) / dp
        # grad sync: reduce-scatter + all-gather of grads over DP
        gsync = 2.0 * grad_bytes * N_total / tp * (dp - 1) / dp
        # Megatron-style TP all-reduces: 2 fwd + 2 bwd (+1 remat) per layer
        tp_ar = 5.0 * 2.0 * (tokens / dp) * D * 2.0 \
            * cfg.num_layers * (tp - 1) / tp
        if cfg.sharding_profile == "ep_only":
            tp_ar = 0.0   # no tensor parallelism: dense weights FSDP-only
            # but FSDP now spans dp·tp devices → regathers cost more
            gather = 2.0 * micro * pb * (dp * tp - 1) / (dp * tp)
        elif cfg.sharding_profile == "ep_replicated":
            # dense replicated (no gathers, AR grads over all devices);
            # experts sharded (model × data) — regather D per microbatch
            n_exp = 2.0 * (N_total - _dense_params(cfg))
            n_dense = 2.0 * _dense_params(cfg)
            tp_ar = 0.0
            gather = 2.0 * micro * (n_exp / tp) * (dp - 1) / dp
            gsync = 2.0 * grad_bytes * (_dense_params(cfg)
                                        + (N_total - _dense_params(cfg))
                                        / tp) * (dp - 1) / dp
        # MoE all-to-all: dispatch + combine, fwd+bwd (tokens·D each way)
        a2a = 0.0
        if cfg.moe_num_experts:
            L_moe = cfg.repeats * sum(1 for _, f in cfg.pattern
                                      if f == "moe")
            a2a = 4.0 * (tokens / dp) * D * 2.0 * L_moe
        total = gather + gsync + tp_ar + a2a
        if pods > 1:
            # the pod axis is pure DP: the cross-pod share of grad sync
            dci = grad_bytes * N_total / tp / pods
            ici = total - dci
        else:
            ici = total
    elif kind == "prefill":
        tp_ar = 2.0 * 2.0 * (tokens / dp) * D * 2.0 * cfg.num_layers \
            * (tp - 1) / tp
        if cfg.sharding_profile == "ep_only":
            tp_ar = 0.0
        a2a = 0.0
        if cfg.moe_num_experts:
            L_moe = cfg.repeats * sum(1 for _, f in cfg.pattern
                                      if f == "moe")
            a2a = 2.0 * (tokens / dp) * D * 2.0 * L_moe
        ici = tp_ar + a2a
    else:
        rows_dev = B / min(dp, B)
        tp_ar = 2.0 * 2.0 * rows_dev * D * 2.0 * cfg.num_layers \
            * (tp - 1) / tp
        ici = tp_ar
    return dict(flops_dev=flops_dev, bytes_dev=bytes_dev,
                ici_bytes=ici, dci_bytes=dci,
                model_flops_dev=(6.0 if kind == "train" else 2.0)
                * N_active * tokens / dev)


def _dense_params(cfg) -> float:
    from repro.models import model as model_lib
    na = model_lib.active_param_count(cfg)
    nt = model_lib.param_count(cfg)
    # expert params = total - active-adjusted share; dense ≈ the rest
    exp_total = (nt - na) / (1 - cfg.moe_top_k / max(cfg.moe_num_experts, 1)) \
        if cfg.moe_num_experts else 0.0
    return max(nt - exp_total, 0.0)


def _factored(arch: str) -> bool:
    from repro.launch.dryrun import FACTORED_OPT
    return arch in FACTORED_OPT


def _kv_rep(cfg, tp, overridden=()) -> float:
    """Effective stored-head replication. The launcher (adapt_config)
    infers it per mesh; an explicit override pins it."""
    if "kv_repeat" in overridden or cfg.kv_repeat > 1:
        return float(cfg.kv_repeat)
    kv = cfg.num_kv_heads
    if cfg.num_heads > 1 and kv < tp and tp % kv == 0 \
            and cfg.num_heads % (kv * (tp // kv)) == 0:
        return tp / kv
    return 1.0


def _moe_read_frac(cfg) -> float:
    """Decode batches re-read most experts: with B tokens over E experts,
    expected touched experts ≈ E·(1-(1-k/E)^B) → weight reads exceed the
    per-token active fraction. Approximate with full expert reads when
    B ≥ E (the decode_32k cells)."""
    if not cfg.moe_num_experts:
        return 1.0
    from repro.models import model as model_lib
    na = model_lib.active_param_count(cfg)
    nt = model_lib.param_count(cfg)
    return nt / na  # active→total correction (B=128 ≥ E for our cells)


# ----------------------------------------------------------------------

def cell_roofline(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    micro = rec.get("microbatches", 1)
    gb = 2.0 if rec.get("grad_acc_dtype") == "bfloat16" else 4.0
    a = analytic_terms(rec["arch"], rec["shape"], rec["mesh"], micro,
                       cfg_overrides=rec.get("cfg_overrides"),
                       grad_bytes=gb)

    # fold the HLO-observed cross-pod share into the DCI split: if the
    # compiled schedule moved a larger fraction across pods than the
    # analytic DP-only model, trust the schedule's ratio.
    colls = rec.get("collectives") or {}
    hlo_wire = sum(v.get("wire_bytes", v.get("bytes", 0))
                   for v in colls.values())
    hlo_x = sum(v.get("cross_pod_wire_bytes", 0) for v in colls.values())
    if hlo_wire > 0 and rec["mesh"] == "multi":
        x_frac = hlo_x / hlo_wire
        total = a["ici_bytes"] + a["dci_bytes"]
        dci = max(a["dci_bytes"], x_frac * total)
        a["dci_bytes"], a["ici_bytes"] = dci, total - dci

    compute_s = a["flops_dev"] / PEAK_FLOPS
    memory_s = a["bytes_dev"] / HBM_BW
    collective_s = a["ici_bytes"] / ICI_BW + a["dci_bytes"] / DCI_BW
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    useful = a["model_flops_dev"] / a["flops_dev"] if a["flops_dev"] else 0
    frac = (a["model_flops_dev"] / PEAK_FLOPS) / step_s if step_s else 0.0
    return dict(
        cell=f"{rec['arch']}|{rec['shape']}|{rec['mesh']}",
        kind=rec.get("kind"),
        **{k: round(v, 6) for k, v in terms.items()},
        dominant=dominant,
        model_flops_per_device=a["model_flops_dev"],
        hlo_body_flops_per_device=rec["cost"]["flops_per_device"],
        useful_flops_ratio=round(useful, 4),
        roofline_fraction=round(frac, 4),
        memory_gib=round(((rec["memory"]["argument_bytes"] or 0)
                          + (rec["memory"]["temp_bytes"] or 0)) / 2**30, 2),
        variant=rec.get("variant"),
    )


def analyze(report=None, quick=False) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun",
                                              "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        r = cell_roofline(rec)
        if r:
            rows.append(r)
    out_path = os.path.join(ARTIFACTS, "roofline.json")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    if report is not None:
        for r in rows:
            report(f"roofline/{r['cell']}",
                   derived=f"dom={r['dominant'][:-2]} "
                           f"c={r['compute_s']*1e3:.2f}ms "
                           f"m={r['memory_s']*1e3:.2f}ms "
                           f"coll={r['collective_s']*1e3:.2f}ms "
                           f"frac={r['roofline_fraction']:.3f}")
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| cell | kind | compute s | memory s | collective s | dominant "
           "| useful/HLO | roofline frac | raw mem GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['cell']} | {r['kind']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | {r['memory_gib']} |")
    return hdr + "\n".join(lines)


if __name__ == "__main__":
    rows = analyze()
    print(markdown_table(rows))
