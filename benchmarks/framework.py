"""Framework-level benchmarks: mesh layout quality, MoE locality routing,
kernel micro-latencies (CPU fallback path — numbers are relative)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import placement, topology
from repro.core.routing import RoutingConfig, expert_steal_table, route
from repro.kernels import ref


def _timeit(fn, *args, reps=5):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def sim_engine(report, quick=False):
    """Simulator-engine throughput rows (full sweep: bench_sim.py)."""
    from benchmarks import bench_sim
    for row in bench_sim.bench(quick=quick, reps=3):
        report(f"sim-engine/{row['workload']}-{row['scale']}/"
               f"{row['scheduler']}/{row['engine']}",
               us=row["warm_s"] * 1e6,
               derived=f"tasks={row['tasks']} "
                       f"tps={row['tasks_per_s']:.0f} "
                       f"cold={row['cold_s']*1e3:.1f}ms "
                       f"speedup={row['speedup']:.2f}x")
    return True


def mesh_layout(report, quick=False):
    """Hop-weighted collective cost: enumeration order vs priority walk.

    On a *healthy* torus the enumeration order is already ring-optimal —
    the paper's walk matters on irregular topologies: after failures
    (the elastic-remesh case, §IV "cores already allocated") the naive
    order of survivors breaks rings, while the priority walk re-packs
    them (this is exactly `plan_elastic_remesh`'s layout step)."""
    cases = [("single-pod", topology.tpu_pod_2d(16, 16), (16, 16)),
             ("multi-pod", topology.multi_pod(2, 16, 16), (2, 16, 16))]
    for name, topo, shape in cases:
        t0 = time.perf_counter()
        perm = placement.device_order_priority(topo, shape)
        t_order = (time.perf_counter() - t0) * 1e6
        base = placement.layout_cost(
            topo, placement.device_order_baseline(topo), shape)
        pri = placement.layout_cost(topo, perm, shape)
        report(f"mesh-layout/{name}", us=t_order,
               derived=f"hops base={base:.3f} priority={pri:.3f} "
                       f"(healthy torus: enumeration already optimal)")

    # degraded topology: random failures, shrink to the largest square
    rng = np.random.RandomState(0)
    topo = topology.tpu_pod_2d(16, 16)
    for frac in (0.05, 0.15):
        failed = set(rng.choice(256, int(256 * frac), replace=False)
                     .tolist())
        survivors = [d for d in range(256) if d not in failed]
        keep = 12 * 12 if len(survivors) >= 144 else 8 * 8
        shape = (12, 12) if keep == 144 else (8, 8)
        sub = topo.restrict(survivors[:])
        # naive: first-k survivors in enumeration order
        base = placement.layout_cost(sub.restrict(list(range(keep))),
                                     np.arange(keep), shape)
        # paper walk, two-stage: compact blob → ring-aware order within it
        blob = placement.device_order_priority(
            sub, (sub.num_cores,))[:keep]
        sub2 = sub.restrict([int(b) for b in blob])
        perm = placement.device_order_priority(sub2, shape)
        pri = placement.layout_cost(sub2, perm, shape)
        report(f"mesh-layout/degraded-{int(frac*100)}pct",
               derived=f"hops naive={base:.3f} priority={pri:.3f} "
                       f"({(1 - pri / base) * 100:+.1f}%)")
    return True


def moe_locality(report, quick=False):
    """Drop fraction + steal distance: vanilla vs DFWSPT vs DFWSRPT."""
    topo = topology.tpu_pod_2d(4, 4)
    E, T = 16, 2048
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (T, E))
    logits = logits.at[:, :4].add(2.5)          # hot experts
    d = topo.core_distance_matrix()
    orig = np.asarray(jnp.argmax(logits, 1))
    for policy, attempts in (("none", 0), ("dfwspt", 3), ("dfwsrpt", 3)):
        tbl = (expert_steal_table(topo, np.arange(E), policy)
               if policy != "none" else None)
        cfg = RoutingConfig(E, top_k=1, capacity=T // E,
                            steal_attempts=attempts,
                            policy=policy if policy != "none" else "dfwspt")
        fn = jax.jit(lambda lg: route(lg, cfg, tbl))
        us = _timeit(fn, logits)
        r = fn(logits)
        e = np.asarray(r["expert"][:, 0])
        moved = (e >= 0) & (e != orig)
        hops = d[orig[moved], e[moved]] if moved.any() else np.array([0])
        report(f"moe-locality/{policy}", us=us,
               derived=f"drop={float(r['drop_fraction']):.3f} "
                       f"steal_hops_mean={hops.mean():.2f}")
    return True


def kernels(report, quick=False):
    """Reference-path kernel latencies (CPU). Pallas kernels execute in
    interpret mode on CPU (correctness harness) — production latencies
    come from the TPU roofline, not from here."""
    key = jax.random.PRNGKey(1)
    S = 512 if quick else 1024

    q = jax.random.normal(key, (1, S, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, S, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, S, 2, 64), jnp.float32)
    report("kernel-ref/attention",
           us=_timeit(jax.jit(lambda q, k, v: ref.attention_ref(q, k, v)),
                      q, k, v),
           derived=f"S={S} GQA8/2")
    report("kernel-ref/attention_chunked",
           us=_timeit(jax.jit(lambda q, k, v:
                              ref.attention_chunked_ref(q, k, v, chunk=256)),
                      q, k, v),
           derived=f"S={S} chunk=256")

    x = jax.random.normal(key, (1, S, 8, 32)) * 0.5
    a = -jnp.abs(jax.random.normal(key, (1, S, 8))) * 0.3
    b = jax.random.normal(key, (1, S, 1, 16)) * 0.3
    c = jax.random.normal(key, (1, S, 1, 16)) * 0.3
    report("kernel-ref/ssd_sequential",
           us=_timeit(jax.jit(lambda *t: ref.ssd_ref(*t)), x, a, b, c),
           derived=f"S={S}")
    report("kernel-ref/ssd_chunked",
           us=_timeit(jax.jit(lambda *t: ref.ssd_chunked_ref(*t, chunk=128)),
                      x, a, b, c),
           derived=f"S={S} chunk=128 (dual form)")

    xg = jax.random.normal(key, (8, 256, 256))
    wg = jax.random.normal(key, (8, 256, 512))
    report("kernel-ref/moe_gmm",
           us=_timeit(jax.jit(ref.moe_gmm_ref), xg, wg),
           derived="E8 C256 D256 F512")

    xr = jax.random.normal(key, (4096, 1024))
    wr = jnp.ones((1024,))
    report("kernel-ref/rmsnorm",
           us=_timeit(jax.jit(ref.rmsnorm_ref), xr, wr),
           derived="4096x1024")
    return True
