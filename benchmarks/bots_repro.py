"""Paper reproduction benchmarks.

One function per paper figure:
  * Figs 5–10  — thread-allocation study: six BOTS benchmarks under
    {bf, cilk, wf} × {baseline Nanos, +NUMA-aware allocation}.
  * Figs 13–15 — NUMA-aware task schedulers: FFT / Sort / Strassen under
    {wf, DFWSPT, DFWSRPT} (all with the allocation technique, as in §VI).

Baseline Nanos model: threads unbound (OS migrations), runtime structures
first-touched on node 0, root arrays spilled from node 0. NUMA model:
priority-bound threads, local runtime data, arrays spilled from the
master's (priority-chosen) node. One common serial reference per
benchmark, as the paper uses one serial time per benchmark.

Each figure suite assembles its whole grid into one
:class:`~repro.core.sim.SweepPlan` and runs it in a single batched
engine call (bit-identical to the per-``simulate()`` loop); the
compiled task tables, victim plans, spill distance vectors, and serial
references are shared across every config of the grid.
"""

from __future__ import annotations

from repro.core import placement, priority, topology
from repro.core.sim import SimParams, SweepPlan, bots, serial_time

TOPO = topology.sunfire_x4600()
PR = priority.priorities(TOPO)
PARAMS = SimParams()
THREADS = (2, 4, 6, 8, 12, 16)
MIGRATION = 0.15

# benchmarks × spill-node count (≈ dataset GB / node GB, paper §V)
SPILL = {"fft": 2, "sort": 3, "strassen": 2, "nqueens": 1,
         "floorplan": 1, "sparselu": 2}


# Workloads are cached across figure suites: the tree→CSR compile and
# the serial-time reference are per-Workload one-time costs, and every
# config of the batched sweeps below reuses them.
_WL_CACHE: dict[str, object] = {}


def _workload(name):
    wl = _WL_CACHE.get(name)
    if wl is None:
        if name == "fft":
            wl = bots.fft(n=1 << 15, cutoff=4)
        elif name == "sort":
            wl = bots.sort(n=1 << 15, cutoff=4)
        else:
            wl = bots.make(name, "medium")
        _WL_CACHE[name] = wl
    return wl


def plan_benchmark(name: str, schedulers=("bf", "cilk", "wf"),
                   threads=THREADS, seed: int = 0):
    """Build the (scheduler × variant × T) grid for one BOTS benchmark.

    Returns ``(plan, keys)`` — run ``plan`` (alone or merged into a
    bigger sweep) and zip the results against ``keys``.
    """
    wl = _workload(name)
    spill0 = placement.first_touch_spill(TOPO, 0, SPILL[name])
    serial = serial_time(TOPO, wl, 0, spill0, PARAMS)
    plan = SweepPlan()
    keys = []
    for T in threads:
        base_cores = list(range(T))
        alloc = priority.allocate_threads(TOPO, T)
        mn = int(TOPO.core_node[alloc[0]])
        spill_n = placement.first_touch_spill(TOPO, mn, SPILL[name], PR)
        for sched in schedulers:
            plan.add(TOPO, base_cores, wl, sched, params=PARAMS,
                     seed=seed, root_data_nodes=spill0,
                     runtime_data_node=0, migration_rate=MIGRATION,
                     serial_reference=serial)
            keys.append((sched, "base", T))
            plan.add(TOPO, alloc, wl, sched, params=PARAMS, seed=seed,
                     root_data_nodes=spill_n, serial_reference=serial)
            keys.append((sched, "numa", T))
    return plan, keys


def run_benchmark(name: str, schedulers=("bf", "cilk", "wf"),
                  threads=THREADS, seed: int = 0):
    """Returns {(sched, variant, T): speedup} for one BOTS benchmark."""
    plan, keys = plan_benchmark(name, schedulers, threads, seed)
    return {k: r.speedup for k, r in zip(keys, plan.run())}


def fig_5_to_10(report, quick=False):
    """Thread-allocation study (paper Figs 5–10)."""
    names = ["floorplan", "sparselu", "fft", "strassen", "sort", "nqueens"]
    threads = (4, 16) if quick else THREADS
    for name in names:
        res = run_benchmark(name, threads=threads)
        for sched in ("bf", "cilk", "wf"):
            b16 = res[(sched, "base", threads[-1])]
            n16 = res[(sched, "numa", threads[-1])]
            gain = (n16 / b16 - 1) * 100
            report(f"bots/{name}/{sched}@{threads[-1]}",
                   derived=f"base={b16:.2f}x numa={n16:.2f}x "
                           f"gain={gain:+.1f}%")
    return True


def fig_13_to_15(report, quick=False):
    """NUMA-aware task schedulers on FFT / Sort / Strassen (Figs 13–15).

    ``dfwshier`` (the policy layer's hierarchical steal variant) rides
    along as an extra column next to the paper's three schedulers.
    """
    threads = (16,) if quick else (2, 4, 8, 16)
    scheds = ("wf", "dfwspt", "dfwsrpt", "dfwshier")
    plan = SweepPlan()
    keys = []
    for name in ("fft", "sort", "strassen"):
        wl = _workload(name)
        spill0 = placement.first_touch_spill(TOPO, 0, SPILL[name])
        serial = serial_time(TOPO, wl, 0, spill0, PARAMS)
        for T in threads:
            alloc = priority.allocate_threads(TOPO, T)
            mn = int(TOPO.core_node[alloc[0]])
            spill = placement.first_touch_spill(TOPO, mn, SPILL[name], PR)
            for sched in scheds:
                plan.add(TOPO, alloc, wl, sched, params=PARAMS,
                         seed=0, root_data_nodes=spill,
                         serial_reference=serial)
                keys.append((name, T, sched))
    speedups = {k: r.speedup for k, r in zip(keys, plan.run())}
    for name in ("fft", "sort", "strassen"):
        T = threads[-1]
        sp = {sched: speedups[(name, T, sched)] for sched in scheds}
        g1 = (sp["dfwspt"] / sp["wf"] - 1) * 100
        g2 = (sp["dfwsrpt"] / sp["wf"] - 1) * 100
        g3 = (sp["dfwshier"] / sp["wf"] - 1) * 100
        report(f"bots-sched/{name}@{T}",
               derived=f"wf={sp['wf']:.2f}x "
                       f"dfwspt={sp['dfwspt']:.2f}x({g1:+.1f}%) "
                       f"dfwsrpt={sp['dfwsrpt']:.2f}x({g2:+.1f}%) "
                       f"dfwshier={sp['dfwshier']:.2f}x({g3:+.1f}%)")
    return True
