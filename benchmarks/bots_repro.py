"""Paper reproduction benchmarks.

One function per paper figure:
  * Figs 5–10  — thread-allocation study: six BOTS benchmarks under
    {bf, cilk, wf} × {baseline Nanos, +NUMA-aware allocation}.
  * Figs 13–15 — NUMA-aware task schedulers: FFT / Sort / Strassen under
    {wf, DFWSPT, DFWSRPT} (all with the allocation technique, as in §VI).

Each figure is one declarative :meth:`Machine.grid` call: the execution
variants are context specs — baseline Nanos is ``binding="linear"``
(OS enumeration order, threads unbound → migrations) + ``spill:K@0``
(runtime and root arrays first-touched on node 0, stock Linux node-id
spill walk), the paper's NUMA model is ``binding="paper"`` (priority
allocation) + ``spill:K`` (spill from the master's priority-chosen
node) — and the cartesian product expands straight into one batched
:class:`~repro.core.sim.SweepPlan` engine call, bit-identical to the
per-``simulate()`` loop. One common serial reference per benchmark, as
the paper uses one serial time per benchmark.

The paper's bars are averages over repeated runs on real hardware; the
figure suites mirror that with a Monte-Carlo seed axis (``SEEDS``
replicas per cell, expanded inside the same batched call and dispatched
across the engine worker pool) and report speedups as mean ± CI95.
"""

from __future__ import annotations

import os

from repro.core import topology
from repro.core.sim import Grid, Machine, SimParams, bots

# Monte-Carlo replicas per grid cell for the figure suites (quick CI
# smoke trims this); error bars are the CI95 of the speedup mean
SEEDS = 32
QUICK_SEEDS = 2

TOPO = topology.sunfire_x4600()
PARAMS = SimParams()
MACHINE = Machine(TOPO, PARAMS)
THREADS = (2, 4, 6, 8, 12, 16)
MIGRATION = 0.15

# benchmarks × spill-node count (≈ dataset GB / node GB, paper §V)
SPILL = {"fft": 2, "sort": 3, "strassen": 2, "nqueens": 1,
         "floorplan": 1, "sparselu": 2}


# Workloads are cached across figure suites: the tree→CSR compile and
# the serial-time reference are per-Workload one-time costs, and every
# config of the batched sweeps below reuses them.
_WL_CACHE: dict[str, object] = {}


def _workload(name):
    wl = _WL_CACHE.get(name)
    if wl is None:
        if name == "fft":
            wl = bots.fft(n=1 << 15, cutoff=4)
        elif name == "sort":
            wl = bots.sort(n=1 << 15, cutoff=4)
        else:
            wl = bots.make(name, "medium")
        _WL_CACHE[name] = wl
    return wl


def variants_k(k: int) -> dict:
    """The figure variants for a ``spill:K`` dataset footprint."""
    return {
        "base": dict(binding="linear", placement=f"spill:{k}@0",
                     runtime_data=0, migration_rate=MIGRATION),
        "numa": dict(binding="paper", placement=f"spill:{k}"),
    }


def variants(name: str) -> dict:
    """The figure variants: baseline Nanos vs the paper's NUMA model."""
    return variants_k(SPILL[name])


def _serial(name: str) -> float:
    """One serial reference per benchmark: the boot core with the
    baseline data placement, as the paper measures it."""
    return MACHINE.serial_time(_workload(name),
                               placement=f"spill:{SPILL[name]}@0")


# Durable-sweep opt-in: REPRO_SIM_STORE=path.jsonl journals every figure
# cell and replays journaled ones, so an interrupted figure campaign
# resumes where it stopped and a fully warm journal replays the grids
# without invoking either engine. One shared store across all figures.
_STORE = None


def _figure_store():
    global _STORE
    path = os.environ.get("REPRO_SIM_STORE")
    if path and _STORE is None:
        from repro.core.sim import ResultStore
        _STORE = ResultStore(path)
    return _STORE


def plan_benchmark(name: str, schedulers=("bf", "cilk", "wf"),
                   threads=THREADS, seed: int = 0, seeds=None) -> Grid:
    """The (scheduler × variant × T) grid for one BOTS benchmark.

    ``seeds`` (a sequence or int shorthand, see :meth:`Machine.grid`)
    expands the Monte-Carlo axis; default is the single ``seed``.
    """
    return MACHINE.grid(
        workloads={name: _workload(name)}, schedulers=schedulers,
        threads=threads, contexts=variants(name),
        seeds=(seed,) if seeds is None else seeds,
        serial_reference=_serial(name), store=_figure_store())


def run_benchmark(name: str, schedulers=("bf", "cilk", "wf"),
                  threads=THREADS, seed: int = 0):
    """Returns {(sched, variant, T): speedup} for one BOTS benchmark."""
    return {(k.scheduler, k.context, k.threads): r.speedup
            for k, r in plan_benchmark(name, schedulers, threads,
                                       seed).run().items()}


def run_benchmark_stats(name: str, schedulers=("bf", "cilk", "wf"),
                        threads=THREADS, seeds=SEEDS):
    """Monte-Carlo form: {(sched, variant, T): CellStats over seeds}."""
    return {(k.scheduler, k.context, k.threads): s
            for k, s in plan_benchmark(name, schedulers, threads,
                                       seeds=seeds).run_stats().items()}


def _pm(stat) -> str:
    """mean ± CI95, the paper-style error bar."""
    return f"{stat.mean:.2f}±{stat.ci95:.2f}"


STUDY_SCHEDS = ("wf", "dfwspt", "dfwsrpt", "dfwshier")
ALLOC_SCHEDS = ("bf", "cilk", "wf")


def traced_machine() -> Machine:
    """The figure machine with event tracing on — the entry point the
    :mod:`analysis` pipeline uses to replay the paper grids with full
    execution forensics. Tracing is observational (results stay
    bit-identical to :data:`MACHINE`'s), so the traced sweep *is* the
    paper sweep."""
    return Machine(TOPO, SimParams(trace=True))


def forensics_plan(machine: Machine, quick: bool = False,
                   seeds=(0, 1), store=None):
    """The single traced sweep behind ``python -m analysis.report``.

    One :meth:`Grid.concat` batch covering both paper studies:

    * scheduler study (Figs 13–15): the study workloads under
      ``STUDY_SCHEDS`` × a thread axis, NUMA variant;
    * thread-allocation study (Figs 5–10): every benchmark under
      ``ALLOC_SCHEDS`` × {base, numa} at the top thread count
      (``wf``/numa cells come from the study grid — no duplicates).

    Returns ``(grid, info)``; ``info`` names the study/alloc workloads
    and the thread axis so the analysis layer can slice the results.
    ``quick`` swaps in fft-small + sparselu (the CI smoke).
    """
    threads = (4, 16) if quick else (2, 4, 8, 16)
    top = threads[-1]
    if quick:
        study = {"fft-small": (bots.fft(n=1 << 10, cutoff=8), 2)}
        small = {"sparselu": (_workload("sparselu"), SPILL["sparselu"])}
    else:
        study = {n: (_workload(n), SPILL[n])
                 for n in ("fft", "sort", "strassen")}
        small = {n: (_workload(n), SPILL[n])
                 for n in ("nqueens", "floorplan", "sparselu")}
    grids = []
    for name, (wl, k) in study.items():
        serial = MACHINE.serial_time(wl, placement=f"spill:{k}@0")
        v = variants_k(k)
        grids.append(machine.grid(
            workloads={name: wl}, schedulers=STUDY_SCHEDS,
            threads=threads, contexts={"numa": v["numa"]}, seeds=seeds,
            serial_reference=serial, store=store))
        grids.append(machine.grid(
            workloads={name: wl}, schedulers=("bf", "cilk"),
            threads=top, contexts=v, seeds=seeds,
            serial_reference=serial, store=store))
        grids.append(machine.grid(
            workloads={name: wl}, schedulers=("wf",), threads=top,
            contexts={"base": v["base"]}, seeds=seeds,
            serial_reference=serial, store=store))
    for name, (wl, k) in small.items():
        serial = MACHINE.serial_time(wl, placement=f"spill:{k}@0")
        grids.append(machine.grid(
            workloads={name: wl}, schedulers=ALLOC_SCHEDS, threads=top,
            contexts=variants_k(k), seeds=seeds,
            serial_reference=serial, store=store))
    info = dict(threads=threads, seeds=tuple(seeds),
                study=tuple(study), alloc=tuple(study) + tuple(small))
    return Grid.concat(grids), info


def fig_trace_forensics(report, quick=False):
    """Execution forensics over the paper sweep (the analysis layer):
    regenerates the figure set plus trace diagnostics under
    ``artifacts/analysis/`` and reports headline forensics per cell."""
    from analysis.report import run_forensics
    res = run_forensics(quick=quick, engine=None,
                        seeds=(0,) if quick else (0, 1))
    for row in res["rows"]:
        report(f"trace/{row.pop('label')}",
               derived=" ".join(f"{k}={v}" for k, v in row.items()))
    report("trace/figures",
           derived=f"{len(res['figures'])} files -> {res['out']}")
    return True


def fig_5_to_10(report, quick=False):
    """Thread-allocation study (paper Figs 5–10), seeds× replicas per
    bar; speedups reported mean ± CI95, gains on the means."""
    names = ["floorplan", "sparselu", "fft", "strassen", "sort", "nqueens"]
    threads = (4, 16) if quick else THREADS
    seeds = QUICK_SEEDS if quick else SEEDS
    for name in names:
        res = run_benchmark_stats(name, threads=threads, seeds=seeds)
        for sched in ("bf", "cilk", "wf"):
            b16 = res[(sched, "base", threads[-1])].speedup
            n16 = res[(sched, "numa", threads[-1])].speedup
            gain = (n16.mean / b16.mean - 1) * 100
            report(f"bots/{name}/{sched}@{threads[-1]}",
                   derived=f"base={_pm(b16)}x numa={_pm(n16)}x "
                           f"gain={gain:+.1f}% (n={seeds})")
    return True


def fig_13_to_15(report, quick=False):
    """NUMA-aware task schedulers on FFT / Sort / Strassen (Figs 13–15).

    ``dfwshier`` (the policy layer's hierarchical steal variant) rides
    along as an extra column next to the paper's three schedulers.
    """
    threads = (16,) if quick else (2, 4, 8, 16)
    seeds = QUICK_SEEDS if quick else SEEDS
    scheds = ("wf", "dfwspt", "dfwsrpt", "dfwshier")
    names = ("fft", "sort", "strassen")
    # per-benchmark spill sizes → one grid per workload, fused into a
    # single batched engine call
    grid = Grid.concat([
        MACHINE.grid(workloads={name: _workload(name)}, schedulers=scheds,
                     threads=threads, seeds=seeds,
                     contexts={"numa": variants(name)["numa"]},
                     serial_reference=_serial(name),
                     store=_figure_store())
        for name in names])
    speedups = {(k.workload, k.threads, k.scheduler): s.speedup
                for k, s in grid.run_stats().items()}
    for name in names:
        T = threads[-1]
        sp = {sched: speedups[(name, T, sched)] for sched in scheds}
        g1 = (sp["dfwspt"].mean / sp["wf"].mean - 1) * 100
        g2 = (sp["dfwsrpt"].mean / sp["wf"].mean - 1) * 100
        g3 = (sp["dfwshier"].mean / sp["wf"].mean - 1) * 100
        report(f"bots-sched/{name}@{T}",
               derived=f"wf={_pm(sp['wf'])}x "
                       f"dfwspt={_pm(sp['dfwspt'])}x({g1:+.1f}%) "
                       f"dfwsrpt={_pm(sp['dfwsrpt'])}x({g2:+.1f}%) "
                       f"dfwshier={_pm(sp['dfwshier'])}x({g3:+.1f}%)")
    return True
