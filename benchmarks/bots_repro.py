"""Paper reproduction benchmarks.

One function per paper figure:
  * Figs 5–10  — thread-allocation study: six BOTS benchmarks under
    {bf, cilk, wf} × {baseline Nanos, +NUMA-aware allocation}.
  * Figs 13–15 — NUMA-aware task schedulers: FFT / Sort / Strassen under
    {wf, DFWSPT, DFWSRPT} (all with the allocation technique, as in §VI).

Each figure is one declarative :meth:`Machine.grid` call: the execution
variants are context specs — baseline Nanos is ``binding="linear"``
(OS enumeration order, threads unbound → migrations) + ``spill:K@0``
(runtime and root arrays first-touched on node 0, stock Linux node-id
spill walk), the paper's NUMA model is ``binding="paper"`` (priority
allocation) + ``spill:K`` (spill from the master's priority-chosen
node) — and the cartesian product expands straight into one batched
:class:`~repro.core.sim.SweepPlan` engine call, bit-identical to the
per-``simulate()`` loop. One common serial reference per benchmark, as
the paper uses one serial time per benchmark.
"""

from __future__ import annotations

from repro.core import topology
from repro.core.sim import Grid, Machine, SimParams, bots

TOPO = topology.sunfire_x4600()
PARAMS = SimParams()
MACHINE = Machine(TOPO, PARAMS)
THREADS = (2, 4, 6, 8, 12, 16)
MIGRATION = 0.15

# benchmarks × spill-node count (≈ dataset GB / node GB, paper §V)
SPILL = {"fft": 2, "sort": 3, "strassen": 2, "nqueens": 1,
         "floorplan": 1, "sparselu": 2}


# Workloads are cached across figure suites: the tree→CSR compile and
# the serial-time reference are per-Workload one-time costs, and every
# config of the batched sweeps below reuses them.
_WL_CACHE: dict[str, object] = {}


def _workload(name):
    wl = _WL_CACHE.get(name)
    if wl is None:
        if name == "fft":
            wl = bots.fft(n=1 << 15, cutoff=4)
        elif name == "sort":
            wl = bots.sort(n=1 << 15, cutoff=4)
        else:
            wl = bots.make(name, "medium")
        _WL_CACHE[name] = wl
    return wl


def variants(name: str) -> dict:
    """The figure variants: baseline Nanos vs the paper's NUMA model."""
    k = SPILL[name]
    return {
        "base": dict(binding="linear", placement=f"spill:{k}@0",
                     runtime_data=0, migration_rate=MIGRATION),
        "numa": dict(binding="paper", placement=f"spill:{k}"),
    }


def _serial(name: str) -> float:
    """One serial reference per benchmark: the boot core with the
    baseline data placement, as the paper measures it."""
    return MACHINE.serial_time(_workload(name),
                               placement=f"spill:{SPILL[name]}@0")


def plan_benchmark(name: str, schedulers=("bf", "cilk", "wf"),
                   threads=THREADS, seed: int = 0) -> Grid:
    """The (scheduler × variant × T) grid for one BOTS benchmark."""
    return MACHINE.grid(
        workloads={name: _workload(name)}, schedulers=schedulers,
        threads=threads, contexts=variants(name), seeds=(seed,),
        serial_reference=_serial(name))


def run_benchmark(name: str, schedulers=("bf", "cilk", "wf"),
                  threads=THREADS, seed: int = 0):
    """Returns {(sched, variant, T): speedup} for one BOTS benchmark."""
    return {(k.scheduler, k.context, k.threads): r.speedup
            for k, r in plan_benchmark(name, schedulers, threads,
                                       seed).run().items()}


def fig_5_to_10(report, quick=False):
    """Thread-allocation study (paper Figs 5–10)."""
    names = ["floorplan", "sparselu", "fft", "strassen", "sort", "nqueens"]
    threads = (4, 16) if quick else THREADS
    for name in names:
        res = run_benchmark(name, threads=threads)
        for sched in ("bf", "cilk", "wf"):
            b16 = res[(sched, "base", threads[-1])]
            n16 = res[(sched, "numa", threads[-1])]
            gain = (n16 / b16 - 1) * 100
            report(f"bots/{name}/{sched}@{threads[-1]}",
                   derived=f"base={b16:.2f}x numa={n16:.2f}x "
                           f"gain={gain:+.1f}%")
    return True


def fig_13_to_15(report, quick=False):
    """NUMA-aware task schedulers on FFT / Sort / Strassen (Figs 13–15).

    ``dfwshier`` (the policy layer's hierarchical steal variant) rides
    along as an extra column next to the paper's three schedulers.
    """
    threads = (16,) if quick else (2, 4, 8, 16)
    scheds = ("wf", "dfwspt", "dfwsrpt", "dfwshier")
    names = ("fft", "sort", "strassen")
    # per-benchmark spill sizes → one grid per workload, fused into a
    # single batched engine call
    grid = Grid.concat([
        MACHINE.grid(workloads={name: _workload(name)}, schedulers=scheds,
                     threads=threads,
                     contexts={"numa": variants(name)["numa"]},
                     serial_reference=_serial(name))
        for name in names])
    speedups = {(k.workload, k.threads, k.scheduler): r.speedup
                for k, r in grid.run().items()}
    for name in names:
        T = threads[-1]
        sp = {sched: speedups[(name, T, sched)] for sched in scheds}
        g1 = (sp["dfwspt"] / sp["wf"] - 1) * 100
        g2 = (sp["dfwsrpt"] / sp["wf"] - 1) * 100
        g3 = (sp["dfwshier"] / sp["wf"] - 1) * 100
        report(f"bots-sched/{name}@{T}",
               derived=f"wf={sp['wf']:.2f}x "
                       f"dfwspt={sp['dfwspt']:.2f}x({g1:+.1f}%) "
                       f"dfwsrpt={sp['dfwsrpt']:.2f}x({g2:+.1f}%) "
                       f"dfwshier={sp['dfwshier']:.2f}x({g3:+.1f}%)")
    return True
