"""Benchmark harness: one entry per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only PREFIX]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="run only benches whose name starts with this")
    args = ap.parse_args()

    rows: list[tuple[str, float | None, str]] = []

    def report(name: str, us: float | None = None, derived: str = ""):
        rows.append((name, us, derived))
        us_s = f"{us:.1f}" if us is not None else ""
        print(f"{name},{us_s},{derived}", flush=True)

    from benchmarks import bots_repro, framework, roofline

    benches = [
        ("bots/figs5-10", lambda: bots_repro.fig_5_to_10(report,
                                                         args.quick)),
        ("bots/figs13-15", lambda: bots_repro.fig_13_to_15(report,
                                                           args.quick)),
        ("bots/trace-forensics",
         lambda: bots_repro.fig_trace_forensics(report, args.quick)),
        ("sim-engine", lambda: framework.sim_engine(report, args.quick)),
        ("mesh-layout", lambda: framework.mesh_layout(report, args.quick)),
        ("moe-locality", lambda: framework.moe_locality(report, args.quick)),
        ("kernels", lambda: framework.kernels(report, args.quick)),
        ("roofline", lambda: roofline.analyze(report, args.quick)),
    ]
    print("name,us_per_call,derived")
    for name, fn in benches:
        if args.only and not name.startswith(args.only):
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the harness running; surface the error
            report(f"{name}/ERROR", derived=f"{type(e).__name__}: {e}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
