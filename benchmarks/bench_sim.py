"""Simulation-engine micro-benchmark: times facade runs
(``Machine.run`` under the cached paper-binding context — the
``simulate()`` code path) across schedulers, workload scales, and
engines, and writes ``BENCH_sim.json`` so future PRs can track
performance trajectories.

Methodology: per configuration we report

  * ``cold_s``  — first call on a freshly built workload (includes the
    one-time tree→CSR compile and the serial-reference walk);
  * ``warm_s``  — best of ``--reps`` steady-state calls (compiled table
    and serial reference cached), the regime the paper-reproduction
    driver (`bots_repro`, batched figure sweeps over 6 reused
    workloads) actually runs in;
  * ``tasks_per_s`` — warm throughput.

A separate ``sweep`` section times the batched ``Machine.grid()`` path
on the fft-medium (5 stock schedulers × 6 thread counts) grid against
the sum of the equivalent warm per-call ``Machine.run()`` loop — the
batch amortizes per-config setup and, on the C engine, runs the whole
grid in one kernel call. The ``parallel`` section times the same grid
across the in-batch worker pool (``workers=1`` vs parallel counts up
to ``cpu_count``; C pthreads / py fork processes), asserting every
parallel result bit-identical to serial dispatch; only the
``workers=1`` wall clock is gated by ``--check`` (as the
``scale="medium+batch"`` results row).

The ``paper+cachecold`` / ``paper+cachehit`` rows measure fresh-process
cold start against an empty vs warmed persistent compile cache
(``warm_s`` = time from process start to the first ``SimResult``; see
``cache_smoke``); every in-process row runs with the compile cache
disabled so build/cold times stay honest.

Engines: ``c`` is the compiled flat-array kernel, ``py`` the pure-Python
flat reference engine (also run when the C kernel is unavailable). Both
are bit-exact replicas of the seed engine (see tests/test_sim_golden).

    PYTHONPATH=src python -m benchmarks.bench_sim [--quick] [--out PATH]

``--check`` re-measures and compares ``warm_s`` per (workload, scale,
scheduler, engine) row against the committed ``BENCH_sim.json``,
exiting non-zero on any >25% regression — the ROADMAP "sim perf
trajectory" gate.
"""

from __future__ import annotations

import argparse
import itertools
import json
import os
import platform
import sys
import time

from repro.core import topology
from repro.core.sim import (SCHEDULERS, Machine, SimParams, bots,
                            ensure_table, reset_engine_cache)
from repro.core.sim import _csim

# in-run trace-capture overhead ceilings (bench_trace rows, gated by
# --check regardless of the committed baseline): the compiled kernel —
# the production warm path — must stay within 15%; the pure-Python
# reference engine pays unavoidable per-event interpreter cost
# (~25% structurally) and gets a looser regression backstop.
TRACE_OVERHEAD_LIMIT = {"c": 15.0, "py": 60.0}

# the five stock schedulers benched against the committed baseline;
# policy-layer additions (dfwshier, ...) get their own rows automatically
STOCK = ("bf", "cilk", "wf", "dfwspt", "dfwsrpt")


def _workloads(quick: bool):
    yield ("fft", "small", lambda: bots.fft(n=1 << 10, cutoff=8))
    yield ("fft", "medium", lambda: bots.fft(n=1 << 15, cutoff=4))
    if not quick:
        yield ("sort", "medium", lambda: bots.sort(n=1 << 15, cutoff=4))
        yield ("fft", "paper", lambda: bots.make("fft", "paper"))
        yield ("sort", "paper", lambda: bots.make("sort", "paper"))
        yield ("strassen", "paper", lambda: bots.make("strassen", "paper"))
        yield ("nqueens", "paper", lambda: bots.make("nqueens", "paper"))
        yield ("sparselu", "paper", lambda: bots.make("sparselu", "paper"))


class _engine_env:
    """Force one engine for a ``with`` block (cache-safe)."""

    def __init__(self, engine: str):
        self.engine = engine

    def __enter__(self):
        self.saved = os.environ.get("REPRO_SIM_ENGINE")
        os.environ["REPRO_SIM_ENGINE"] = self.engine

    def __exit__(self, *exc):
        if self.saved is None:
            os.environ.pop("REPRO_SIM_ENGINE", None)
        else:
            os.environ["REPRO_SIM_ENGINE"] = self.saved
        reset_engine_cache()


def _engines():
    return ["py"] if _csim.load() is None else ["c", "py"]


def bench(quick: bool = False, reps: int = 5, threads: int = 16):
    machine = Machine(topology.sunfire_x4600())
    # the paper's priority binding, compiled once and cached
    ctx = machine.context(threads, binding="paper")
    engines = _engines()
    for name, scale, build in _workloads(quick):
        # the py engine sits out the ≥1M-task tier (minutes per call;
        # the C kernel owns it) — skip before paying the build cost
        scale_engines = [e for e in engines
                         if not (e == "py" and scale == "paper")]
        if not scale_engines:
            continue
        schedulers = tuple(SCHEDULERS) if scale != "paper" \
            else ("wf", "dfwsrpt")
        for engine in scale_engines:
            with _engine_env(engine):
                for sched in schedulers:
                    # cold: fresh workload object, nothing cached — the
                    # cold_s rows track the one-time tree/table build +
                    # compile + serial-reference walk per row
                    t0 = time.perf_counter()
                    wl_cold = build()
                    build_s = time.perf_counter() - t0
                    t0 = time.perf_counter()
                    r = machine.run(wl_cold, sched, seed=0, context=ctx)
                    cold_s = time.perf_counter() - t0
                    # warm: steady state (table + serial ref cached)
                    warm = []
                    for _ in range(reps):
                        t0 = time.perf_counter()
                        r = machine.run(wl_cold, sched, seed=0, context=ctx)
                        warm.append(time.perf_counter() - t0)
                    warm_s = min(warm)
                    tasks = ensure_table(wl_cold).n
                    yield dict(
                        workload=name, scale=scale, tasks=tasks,
                        scheduler=sched, engine=engine, threads=threads,
                        build_s=round(build_s, 6), cold_s=round(cold_s, 6),
                        warm_s=round(warm_s, 6),
                        tasks_per_s=round(tasks / warm_s, 1),
                        makespan=r.makespan, speedup=round(r.speedup, 4),
                        steals=r.steals)


def bench_fault_hook(reps: int = 5, threads: int = 16):
    """Faults-off overhead rows: fft-medium under a compiled-but-neutral
    fault plan (the engines' fault hook runs, perturbing nothing).

    Keyed ``scale="medium+faulthook"`` so ``--check`` gates the hook's
    overhead against the committed baseline the same way as every other
    row — the plain fft-medium rows must stay ≈ the pre-fault-layer
    numbers, and these rows must stay ≈ the plain ones.
    """
    machine = Machine(topology.sunfire_x4600())
    wl = bots.fft(n=1 << 15, cutoff=4)
    # severity-0 straggler: has_faults is set, speeds all stay 1.0
    ctx = machine.context(threads, binding="paper", faults="straggler:0@0")
    for engine in _engines():
        with _engine_env(engine):
            for sched in ("dfwsrpt",):
                machine.run(wl, sched, seed=0, context=ctx)  # warm caches
                warm = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    r = machine.run(wl, sched, seed=0, context=ctx)
                    warm.append(time.perf_counter() - t0)
                warm_s = min(warm)
                tasks = ensure_table(wl).n
                yield dict(
                    workload="fft", scale="medium+faulthook", tasks=tasks,
                    scheduler=sched, engine=engine, threads=threads,
                    build_s=0.0, cold_s=0.0, warm_s=round(warm_s, 6),
                    tasks_per_s=round(tasks / warm_s, 1),
                    makespan=r.makespan, speedup=round(r.speedup, 4),
                    steals=r.steals, reclaimed=r.reclaimed,
                    reexec=r.reexec,
                    fault_lost=round(r.fault_lost, 4))


def bench_trace(reps: int = 5, threads: int = 16):
    """Trace-capture overhead rows: fft-medium under full event
    tracing (``SimParams(trace=True)``) vs the plain warm path.

    Keyed ``scale="medium+trace"``; ``warm_s`` is the *traced* warm
    time, ``untraced_s`` the same-process untraced re-measurement, and
    ``trace_overhead_pct`` their fresh in-run ratio. ``--check`` gates
    the overhead against :data:`TRACE_OVERHEAD_LIMIT` directly — a new
    row has no committed-baseline entry, so the usual warm_s
    comparison cannot see it.
    """
    plain = Machine(topology.sunfire_x4600())
    traced = Machine(topology.sunfire_x4600(), SimParams(trace=True))
    wl = bots.fft(n=1 << 15, cutoff=4)
    tasks = ensure_table(wl).n
    for engine in _engines():
        with _engine_env(engine):
            ctx = plain.context(threads, binding="paper")
            tctx = traced.context(threads, binding="paper")
            for sched in ("dfwsrpt",):
                def warm(machine, c):
                    machine.run(wl, sched, seed=0, context=c)
                    best = float("inf")
                    r = None
                    for _ in range(reps):
                        t0 = time.perf_counter()
                        r = machine.run(wl, sched, seed=0, context=c)
                        best = min(best, time.perf_counter() - t0)
                    return best, r
                plain_s, r0 = warm(plain, ctx)
                traced_s, r = warm(traced, tctx)
                assert r == r0, "traced run diverged from untraced"
                tr = r.trace
                yield dict(
                    workload="fft", scale="medium+trace", tasks=tasks,
                    scheduler=sched, engine=engine, threads=threads,
                    build_s=0.0, cold_s=0.0,
                    warm_s=round(traced_s, 6),
                    untraced_s=round(plain_s, 6),
                    trace_overhead_pct=round(
                        (traced_s / plain_s - 1) * 100, 1),
                    events=int(tr.n_exec + tr.n_steal + tr.n_mig),
                    tasks_per_s=round(tasks / traced_s, 1),
                    makespan=r.makespan, speedup=round(r.speedup, 4),
                    steals=r.steals)


def bench_sweep(reps: int = 3):
    """Batched-sweep amortization: fft-medium, 5 schedulers × 6 thread
    counts, one ``Machine.grid()`` wall-clock vs the sum of warm
    per-call ``Machine.run()``."""
    machine = Machine(topology.sunfire_x4600())
    wl = bots.fft(n=1 << 15, cutoff=4)
    thread_counts = (2, 4, 6, 8, 12, 16)

    def make_grid():
        return machine.grid(workloads=[wl], schedulers=STOCK,
                            threads=thread_counts)

    cells = make_grid().keys
    out = []
    for engine in _engines():
        with _engine_env(engine):
            # warm every shared cache (tables, plans, serial refs) so
            # both timings measure the steady-state dispatch regime
            for k in cells:
                machine.run(wl, k.scheduler, seed=k.seed,
                            threads=k.threads)
            loop_s = float("inf")
            sweep_s = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                loop_res = [machine.run(wl, k.scheduler, seed=k.seed,
                                        threads=k.threads) for k in cells]
                loop_s = min(loop_s, time.perf_counter() - t0)
                t0 = time.perf_counter()
                sweep_res = make_grid().run()
                sweep_s = min(sweep_s, time.perf_counter() - t0)
            assert list(sweep_res.values()) == loop_res, \
                "sweep diverged from per-call loop"
            out.append(dict(
                grid="fft-medium x 5 sched x 6 T", configs=len(cells),
                engine=engine, loop_s=round(loop_s, 6),
                sweep_s=round(sweep_s, 6),
                amortization=round(loop_s / sweep_s, 3)))
    return out


def bench_parallel(reps: int = 3, quick: bool = False):
    """Batch-throughput rows: the fft-medium 5-sched × 6-T grid
    dispatched across the in-batch worker pool (C pthreads / py
    fork processes) at workers=1 vs parallel counts.

    Returns ``(gated, detail)``. ``gated`` is one ``results`` row per
    engine — ``scale="medium+batch"``, ``warm_s`` = the *workers=1*
    grid wall clock — so ``--check`` gates only the serial-dispatch
    row (parallel wall clock on a shared container is noise); its
    ``speedup`` field records workers=cpu_count vs workers=1. The
    per-worker-count measurements (wall_s, cells/sec, speedup_vs_1,
    every result asserted bit-identical to workers=1) go ungated into
    the ``parallel`` section of ``BENCH_sim.json``.
    """
    machine = Machine(topology.sunfire_x4600())
    wl = bots.fft(n=1 << 15, cutoff=4)
    thread_counts = (2, 4, 6, 8, 12, 16)
    ncpu = os.cpu_count() or 1
    worker_counts = sorted({1, 2, ncpu} if quick else {1, 2, 4, ncpu})
    gated, detail = [], []
    for engine in _engines():
        with _engine_env(engine):
            grid = machine.grid(workloads=[wl], schedulers=STOCK,
                                threads=thread_counts)
            n = len(grid.keys)
            base_res = grid.run(workers=1)   # warm every shared cache
            wall = {}
            for w in worker_counts:
                best = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    res = grid.run(workers=w)
                    best = min(best, time.perf_counter() - t0)
                assert res == base_res, \
                    f"workers={w} diverged from workers=1 ({engine})"
                wall[w] = best
                detail.append(dict(
                    grid="fft-medium x 5 sched x 6 T", configs=n,
                    engine=engine, workers=w, cpu_count=ncpu,
                    wall_s=round(best, 6),
                    cells_per_s=round(n / best, 2),
                    speedup_vs_1=round(wall[1] / best, 3)))
            tasks = ensure_table(wl).n
            # real aggregates over the grid (batch rows have no single
            # cell to report); speedup has no meaning for a batch row —
            # null, never a placeholder (the workers=N ratio lives in
            # the parallel detail section as speedup_vs_1)
            gated.append(dict(
                workload="fft", scale="medium+batch", tasks=tasks,
                scheduler="batch", engine=engine, threads=16,
                build_s=0.0, cold_s=0.0, warm_s=round(wall[1], 6),
                tasks_per_s=round(tasks * n / wall[1], 1),
                makespan=round(sum(r.makespan
                                   for r in base_res.values()), 6),
                speedup=None,
                steals=sum(r.steals for r in base_res.values())))
    return gated, detail


def bench_store(reps: int = 3, quick: bool = False):
    """Durable-sweep overhead rows: the fft-medium 5-sched × 6-T grid
    run through a :class:`~repro.core.sim.ResultStore`.

    Two gated rows per engine, keyed like every other results row:

    * ``scale="medium+journal"`` — a *cold* store (fresh journal each
      rep): every cell simulates and commits one JSONL line, so
      ``warm_s`` measures journaling overhead on top of the plain
      ``medium+batch`` row it must stay ≈ equal to.
    * ``scale="medium+storehit"`` — a *fully warm* store: every cell
      replays from the in-memory index without invoking the engine, so
      ``warm_s`` is the pure store-hit sweep latency (and is asserted
      engine-free by running with the workers pool untouched).
    """
    import tempfile

    machine = Machine(topology.sunfire_x4600())
    wl = bots.fft(n=1 << 15, cutoff=4)
    thread_counts = (2, 4, 6, 8, 12, 16)
    from repro.core.sim import ResultStore
    rows = []
    for engine in _engines():
        with _engine_env(engine):
            grid = machine.grid(workloads=[wl], schedulers=STOCK,
                                threads=thread_counts)
            n = len(grid.keys)
            base_res = grid.run(workers=1)   # warm every shared cache
            with tempfile.TemporaryDirectory() as tmp:
                cold = float("inf")
                for i in range(reps):
                    path = os.path.join(tmp, f"j{i}.jsonl")
                    t0 = time.perf_counter()
                    res = grid.run(workers=1, store=path)
                    cold = min(cold, time.perf_counter() - t0)
                    assert res == base_res, "journaled run diverged"
                warm_store = ResultStore(os.path.join(tmp, "warm.jsonl"))
                grid.run(workers=1, store=warm_store)
                hit = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    res = grid.run(workers=1, store=warm_store)
                    hit = min(hit, time.perf_counter() - t0)
                assert res == base_res, "store replay diverged"
                warm_store.close()
            tasks = ensure_table(wl).n
            # real grid aggregates (summed over cells); speedup is not
            # defined for a batch row — null, never a 0.0 placeholder
            agg_makespan = round(sum(r.makespan
                                     for r in base_res.values()), 6)
            agg_steals = sum(r.steals for r in base_res.values())
            for scale, wall in (("medium+journal", cold),
                                ("medium+storehit", hit)):
                rows.append(dict(
                    workload="fft", scale=scale, tasks=tasks,
                    scheduler="batch", engine=engine, threads=16,
                    build_s=0.0, cold_s=0.0, warm_s=round(wall, 6),
                    tasks_per_s=round(tasks * n / wall, 1),
                    makespan=agg_makespan, speedup=None,
                    steals=agg_steals))
    return rows


def bench_cache(quick: bool = False):
    """Cold-start rows: ``paper+cachecold`` / ``paper+cachehit``.

    Each row is measured in a *fresh interpreter* (see ``cache_smoke``)
    against an empty vs warmed compile cache: ``build_s`` is the
    ``bots.make`` wall clock, ``cold_s`` the first ``Machine.run``
    (serial reference + kernel build included), and ``warm_s`` —
    the gated quantity — their sum: time from process start
    (post-import) to the first ``SimResult``. The cachehit row is the
    <0.3 s cold-start acceptance the compile cache exists for.
    """
    if quick or "c" not in _engines():
        return []
    from benchmarks.cache_smoke import smoke
    rows = []
    cold, warm = smoke("c", verbose=False)
    for scale, rec in (("paper+cachecold", cold),
                       ("paper+cachehit", warm)):
        rows.append(dict(
            workload=rec["workload"], scale=scale, tasks=rec["tasks"],
            scheduler=rec["scheduler"], engine="c",
            threads=rec["threads"],
            build_s=round(rec["make_s"], 6),
            cold_s=round(rec["run_s"], 6),
            warm_s=round(rec["first_result_s"], 6),
            tasks_per_s=round(rec["tasks"] / rec["first_result_s"], 1),
            makespan=rec["makespan"],
            speedup=round(rec["speedup"], 4), steals=rec["steals"]))
    return rows


def check(rows, baseline_path: str, threshold: float = 0.25,
          abs_slack: float = 0.001) -> int:
    """Compare fresh warm_s against the committed baseline; returns the
    number of regressions (and prints each).

    A row regresses when it is both >threshold relatively *and*
    >abs_slack seconds absolutely slower — sub-millisecond rows on a
    shared container jitter past any pure ratio test.
    """
    with open(baseline_path) as f:
        base = json.load(f)
    base_by_key = {(r["workload"], r["scale"], r["scheduler"], r["engine"]):
                   r for r in base.get("results", [])}
    regressions = 0
    # losing a whole engine (e.g. the C toolchain breaking, so only py
    # rows get measured) must fail the gate, not silently shrink it
    fresh_engines = {row["engine"] for row in rows}
    lost = {r["engine"] for r in base.get("results", [])} - fresh_engines
    for engine in sorted(lost):
        regressions += 1
        print(f"REGRESSION engine {engine!r}: present in {baseline_path} "
              f"but produced no rows in this run", file=sys.stderr)
    for row in rows:
        key = (row["workload"], row["scale"], row["scheduler"],
               row["engine"])
        ref = base_by_key.get(key)
        if ref is None:
            continue  # new row (new scheduler/tier) — nothing to gate on
        if row.get("warm_s") is None or ref.get("warm_s") is None:
            continue  # null metric (batch rows) — nothing to gate on
        ratio = row["warm_s"] / ref["warm_s"]
        if ratio > 1.0 + threshold and row["warm_s"] - ref["warm_s"] > abs_slack:
            regressions += 1
            print(f"REGRESSION {'/'.join(key)}: warm_s "
                  f"{ref['warm_s']:.6f}s -> {row['warm_s']:.6f}s "
                  f"({(ratio - 1) * 100:+.1f}%)", file=sys.stderr)
    # in-run trace-overhead gate: fresh traced-vs-untraced ratio from
    # the same process (baseline-independent, so new rows are covered)
    for row in rows:
        pct = row.get("trace_overhead_pct")
        if pct is None:
            continue
        limit = TRACE_OVERHEAD_LIMIT.get(row["engine"], 15.0)
        if pct > limit:
            regressions += 1
            print(f"REGRESSION {row['workload']}/{row['scale']}/"
                  f"{row['engine']}: trace overhead {pct:+.1f}% > "
                  f"{limit:.0f}% ceiling", file=sys.stderr)
    checked = sum(1 for row in rows
                  if (row["workload"], row["scale"], row["scheduler"],
                      row["engine"]) in base_by_key)
    print(f"# --check: {checked} rows vs {baseline_path}, "
          f"{regressions} regression(s) over {threshold:.0%}")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--threads", type=int, default=16)
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_sim.json; a --quick "
                         "run defaults to BENCH_sim_quick.json so the "
                         "committed full baseline isn't overwritten)")
    ap.add_argument("--check", action="store_true",
                    help="compare fresh warm_s against the committed "
                         "baseline; exit non-zero on regression "
                         "(does not rewrite the baseline)")
    ap.add_argument("--baseline", default="BENCH_sim.json",
                    help="baseline file for --check")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="--check relative regression threshold "
                         "(0.25 = 25%%; CI uses 1.5 — hosted runners "
                         "are not the baseline container)")
    args = ap.parse_args()

    # In-process rows measure true build/compile costs: run them with
    # the persistent compile cache disabled so a warm user cache can't
    # turn cold_s/build_s into cache-hit times. The cache's own win is
    # measured explicitly by bench_cache in fresh child processes
    # (which set their own REPRO_SIM_CACHE).
    os.environ["REPRO_SIM_CACHE"] = "0"
    from repro.core.sim import reset_cache
    reset_cache()

    rows = []
    print("workload,scale,tasks,scheduler,engine,build_s,cold_s,warm_s,"
          "tasks_per_s,speedup,steals")
    batch_rows, parallel_rows = bench_parallel(
        reps=1 if args.quick else 3, quick=args.quick)
    for row in itertools.chain(
            bench(args.quick, args.reps, args.threads),
            bench_fault_hook(args.reps, args.threads),
            bench_trace(args.reps, args.threads),
            batch_rows,
            bench_store(reps=1 if args.quick else 3, quick=args.quick),
            bench_cache(quick=args.quick)):
        rows.append(row)
        spd = "null" if row["speedup"] is None else row["speedup"]
        print(f"{row['workload']},{row['scale']},{row['tasks']},"
              f"{row['scheduler']},{row['engine']},{row['build_s']:.3f},"
              f"{row['cold_s']:.4f},{row['warm_s']:.4f},"
              f"{row['tasks_per_s']:.0f},{spd},{row['steals']}",
              flush=True)
    for p in parallel_rows:
        print(f"# parallel[{p['engine']}] workers={p['workers']}"
              f"/{p['cpu_count']}: wall={p['wall_s']:.4f}s "
              f"cells/s={p['cells_per_s']:.1f} "
              f"speedup={p['speedup_vs_1']:.2f}x")

    if args.check:
        sys.exit(1 if check(rows, args.baseline, args.threshold) else 0)

    # the sweep section is a full 30-config grid per engine — skip it in
    # quick smoke runs
    sweep_rows = [] if args.quick else bench_sweep()
    for s in sweep_rows:
        print(f"# sweep[{s['engine']}] {s['grid']}: loop={s['loop_s']:.4f}s "
              f"sweep={s['sweep_s']:.4f}s "
              f"amortization={s['amortization']:.2f}x")

    doc = dict(
        meta=dict(
            host=platform.node(), python=platform.python_version(),
            c_kernel=_csim.load() is not None,
            c_kernel_error=_csim.load_error,
            timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
            cpu_count=os.cpu_count(),
            note="warm_s is best-of-reps steady state; cold_s includes "
                 "the one-time tree->CSR compile + serial reference. "
                 "sweep rows time the batched SweepPlan path against "
                 "the per-call loop on the same grid; parallel rows "
                 "time the same grid across the in-batch worker pool "
                 "(scale='medium+batch' results rows gate workers=1; "
                 "parallel speedup is bounded by cpu_count). "
                 "medium+journal / medium+storehit rows gate the "
                 "durable-sweep path: cold-journal overhead and the "
                 "warm store-hit replay (no engine calls). Batch rows "
                 "report summed makespan/steals over the grid and "
                 "speedup=null (not defined for a batch). "
                 "paper+cachecold / paper+cachehit rows are fresh-"
                 "process cold starts against an empty vs warmed "
                 "compile cache; their warm_s is time-to-first-"
                 "SimResult (build_s + cold_s)."),
        results=rows,
        sweep=sweep_rows,
        parallel=parallel_rows)
    out = args.out or ("BENCH_sim_quick.json" if args.quick
                       else "BENCH_sim.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"# wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
