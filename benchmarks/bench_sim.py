"""Simulation-engine micro-benchmark: times `simulate()` across
schedulers, workload scales, and engines, and writes ``BENCH_sim.json``
so future PRs can track performance trajectories.

Methodology: per configuration we report

  * ``cold_s``  — first call on a freshly built workload (includes the
    one-time tree→CSR compile and the serial-reference walk);
  * ``warm_s``  — best of ``--reps`` steady-state calls (compiled table
    and serial reference cached), the regime the paper-reproduction
    driver (`bots_repro`, ~230 simulate calls over 6 reused workloads)
    actually runs in;
  * ``tasks_per_s`` — warm throughput.

Engines: ``c`` is the compiled flat-array kernel, ``py`` the pure-Python
flat reference engine (also run when the C kernel is unavailable). Both
are bit-exact replicas of the seed engine (see tests/test_sim_golden).

    PYTHONPATH=src python -m benchmarks.bench_sim [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core import priority, topology
from repro.core.sim import SCHEDULERS, bots, ensure_table, simulate
from repro.core.sim import _csim


def _workloads(quick: bool):
    yield ("fft", "small", lambda: bots.fft(n=1 << 10, cutoff=8))
    yield ("fft", "medium", lambda: bots.fft(n=1 << 15, cutoff=4))
    if not quick:
        yield ("sort", "medium", lambda: bots.sort(n=1 << 15, cutoff=4))
        yield ("fft", "paper", lambda: bots.make("fft", "paper"))
        yield ("sort", "paper", lambda: bots.make("sort", "paper"))
        yield ("strassen", "paper", lambda: bots.make("strassen", "paper"))


def bench(quick: bool = False, reps: int = 5, threads: int = 16):
    topo = topology.sunfire_x4600()
    alloc = priority.allocate_threads(topo, threads)
    engines = ["py"] if _csim.load() is None else ["c", "py"]
    saved_engine = os.environ.get("REPRO_SIM_ENGINE")
    try:
        for name, scale, build in _workloads(quick):
            # the py engine sits out the ≥1M-task tier (minutes per call;
            # the C kernel owns it) — skip before paying the build cost
            scale_engines = [e for e in engines
                             if not (e == "py" and scale == "paper")]
            if not scale_engines:
                continue
            schedulers = SCHEDULERS if scale != "paper" else ("wf", "dfwsrpt")
            for engine in scale_engines:
                os.environ["REPRO_SIM_ENGINE"] = engine
                for sched in schedulers:
                    # cold: fresh workload object, nothing cached — the
                    # cold_s rows track the one-time tree/table build +
                    # compile + serial-reference walk per row
                    t0 = time.perf_counter()
                    wl_cold = build()
                    build_s = time.perf_counter() - t0
                    t0 = time.perf_counter()
                    r = simulate(topo, alloc, wl_cold, sched, seed=0)
                    cold_s = time.perf_counter() - t0
                    # warm: steady state (table + serial ref cached)
                    warm = []
                    for _ in range(reps):
                        t0 = time.perf_counter()
                        r = simulate(topo, alloc, wl_cold, sched, seed=0)
                        warm.append(time.perf_counter() - t0)
                    warm_s = min(warm)
                    tasks = ensure_table(wl_cold).n
                    yield dict(
                        workload=name, scale=scale, tasks=tasks,
                        scheduler=sched, engine=engine, threads=threads,
                        build_s=round(build_s, 6), cold_s=round(cold_s, 6),
                        warm_s=round(warm_s, 6),
                        tasks_per_s=round(tasks / warm_s, 1),
                        makespan=r.makespan, speedup=round(r.speedup, 4),
                        steals=r.steals)
    finally:
        if saved_engine is None:
            os.environ.pop("REPRO_SIM_ENGINE", None)
        else:
            os.environ["REPRO_SIM_ENGINE"] = saved_engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--threads", type=int, default=16)
    ap.add_argument("--out", default="BENCH_sim.json")
    args = ap.parse_args()

    rows = []
    print("workload,scale,tasks,scheduler,engine,build_s,cold_s,warm_s,"
          "tasks_per_s,speedup,steals")
    for row in bench(args.quick, args.reps, args.threads):
        rows.append(row)
        print(f"{row['workload']},{row['scale']},{row['tasks']},"
              f"{row['scheduler']},{row['engine']},{row['build_s']:.3f},"
              f"{row['cold_s']:.4f},{row['warm_s']:.4f},"
              f"{row['tasks_per_s']:.0f},{row['speedup']},{row['steals']}",
              flush=True)

    doc = dict(
        meta=dict(
            host=platform.node(), python=platform.python_version(),
            c_kernel=_csim.load() is not None,
            c_kernel_error=_csim.load_error,
            timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
            note="warm_s is best-of-reps steady state; cold_s includes "
                 "the one-time tree->CSR compile + serial reference."),
        results=rows)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"# wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
