"""Kill-and-resume smoke test for the durable sweep layer.

The CI-facing end-to-end drill for the resume guarantee, run under both
engines (``REPRO_SIM_ENGINE`` ∈ {py, c}): a child process runs a
journaled grid with each commit artificially slowed; the parent SIGKILLs
it mid-campaign — the strongest interruption there is, no cleanup
handlers run, possibly tearing the final journal line — then resumes
from the surviving journal and asserts

  1. the resumed results are bit-identical to an uninterrupted run, and
  2. only the cells missing from the journal were re-simulated (counted
     by wrapping the engine batch entry points).

    PYTHONPATH=src python -m benchmarks.durable_smoke

Exits 0 on success (or when REPRO_SIM_ENGINE=c without a C toolchain —
printed and skipped), 1 on any violated assertion.
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import tempfile
import time

from repro.core import topology
from repro.core.sim import Machine, ResultStore, bots
from repro.core.sim import _csim, _engine_py

# per-commit delay in the child: slow enough for the parent to observe
# a partially written journal, fast enough to keep the smoke under ~30s
COMMIT_DELAY = 0.15
SEEDS = 4


def _grid(machine):
    wl = bots.fft(n=1 << 10, cutoff=8)
    return machine.grid(workloads=[wl], schedulers=("wf", "dfwsrpt"),
                        threads=(4, 16), seeds=SEEDS)


def child(journal: str) -> None:
    """Run the journaled grid with slowed commits until SIGKILLed."""
    orig = ResultStore._commit

    def slow_commit(self, line):
        orig(self, line)
        time.sleep(COMMIT_DELAY)

    ResultStore._commit = slow_commit
    grid = _grid(Machine(topology.sunfire_x4600()))
    grid.run(workers=1, store=journal)
    # reaching here means the parent failed to kill us in time; the
    # journal is fully warm, which the parent detects and reports
    print("child: completed without being killed", flush=True)


def _count_journal_entries(journal: str) -> int:
    try:
        with open(journal, "r", encoding="utf-8") as fh:
            raw = fh.read()
    except FileNotFoundError:
        return 0
    lines = raw.split("\n")
    if lines and not raw.endswith("\n"):
        lines.pop()              # torn tail: not yet a committed entry
    return sum(1 for ln in lines if ln and '"k"' in ln)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", metavar="JOURNAL", default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        child(args.child)
        return 0

    engine = os.environ.get("REPRO_SIM_ENGINE", "auto")
    if engine == "c" and _csim.load() is None:
        print(f"durable-smoke: SKIP (C kernel unavailable: "
              f"{_csim.load_error})")
        return 0

    machine = Machine(topology.sunfire_x4600())
    grid = _grid(machine)
    base = grid.run(workers=1)
    total = len(base)
    print(f"durable-smoke: engine={engine} grid={total} cells")

    with tempfile.TemporaryDirectory(prefix="durable-smoke-") as tmp:
        journal = os.path.join(tmp, "sweep.jsonl")
        env = dict(os.environ, PYTHONPATH="src")
        proc = subprocess.Popen(
            [sys.executable, "-m", "benchmarks.durable_smoke",
             "--child", journal],
            env=env, cwd=os.path.join(os.path.dirname(__file__), ".."))

        # wait for a partial journal, then SIGKILL mid-campaign
        deadline = time.monotonic() + 120
        while _count_journal_entries(journal) < 3:
            if proc.poll() is not None or time.monotonic() > deadline:
                print("durable-smoke: FAIL — child exited before a "
                      "partial journal formed", file=sys.stderr)
                return 1
            time.sleep(0.05)
        proc.send_signal(signal.SIGKILL)
        proc.wait()

        done = _count_journal_entries(journal)
        if not 0 < done < total:
            print(f"durable-smoke: FAIL — journal has {done}/{total} "
                  "entries; the kill missed the mid-campaign window",
                  file=sys.stderr)
            return 1
        print(f"durable-smoke: killed child with {done}/{total} cells "
              "journaled")

        # resume, counting how many cells each engine actually simulates
        simulated = []

        def wrap(mod):
            orig = mod.run_batch

            def counting(ctxs, workers=1):
                ctxs = list(ctxs)
                simulated.append(len(ctxs))
                return orig(ctxs, workers=workers)

            mod.run_batch = counting

        wrap(_engine_py)
        if _csim.load() is not None:
            wrap(_csim)
        resumed = grid.run(workers=1, resume=journal)

    if resumed != base:
        print("durable-smoke: FAIL — resumed run is not bit-identical "
              "to the uninterrupted run", file=sys.stderr)
        return 1
    if sum(simulated) != total - done:
        print(f"durable-smoke: FAIL — resume re-simulated "
              f"{sum(simulated)} cells, expected {total - done}",
              file=sys.stderr)
        return 1
    print(f"durable-smoke: OK — resume re-simulated {sum(simulated)} "
          f"missing cells, replayed {done}, all {total} bit-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
