"""Robustness study: scheduler degradation under injected faults.

The paper evaluates its NUMA-aware schedulers on perfectly healthy
cores; this driver measures how gracefully each strategy degrades when
the machine misbehaves — the regime where load-balancing policies
actually separate. For every scheduler (the five stock policies plus
the hierarchical ``dfwshier``) it sweeps a fault-intensity axis per
fault kind and reports *makespan inflation* relative to the same
(scheduler, seed) cell with faults off:

  * ``straggler`` — the master-thread core slowed ``(1+S)x``,
    S ∈ {0.25 .. 2.0}: work-stealing should route around it;
  * ``preempt``   — Poisson(N) offline windows per thread, queued tasks
    reclaimed and re-stolen; tests recovery from transient loss;
  * ``fail``      — K threads die permanently at t=span/4, their work
    deterministically re-executed by survivors.

Each (kind, intensity) point is one batched :meth:`Machine.grid` call
over schedulers × seeds, run under ``strict=False``: a pathological
cell (e.g. a stall under an extreme fault) degrades to a reported
:class:`CellError` row instead of aborting the sweep — this driver
dogfoods the hardened harness it ships with.

    PYTHONPATH=src python -m benchmarks.bots_robustness [--quick]
        [--scale {medium,paper}] [--threads N] [--seeds N]
        [--workers N] [--out PATH]

``--quick`` (the CI smoke): fft-small only, one seed, a trimmed fault
axis, and a py↔C engine-parity assertion on every cell.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.core import topology
from repro.core.sim import CellError, Machine, SimParams, bots, \
    reset_engine_cache
from repro.core.sim import _csim

SCHEDULERS = ("bf", "cilk", "wf", "dfwspt", "dfwsrpt", "dfwshier")

# fault-intensity axes; each entry is (label, spec builder(master_core))
AXES = {
    "straggler": [0.25, 0.5, 1.0, 2.0],
    "preempt": [0.5, 1.0, 2.0, 4.0],
    "fail": [1, 2, 4],
}
QUICK_AXES = {
    "straggler": [1.0],
    "preempt": [2.0],
    "fail": [2],
}


def _specs(kind: str, x, master: int, span: float):
    if kind == "straggler":
        return f"straggler:{x}@{master}"
    if kind == "preempt":
        return f"preempt:{x}@{span / 8:g}"
    return f"fail:{int(x)}@{span / 4:g}"


def _workload(quick: bool, scale: str):
    if quick:
        return "fft-small", bots.fft(n=1 << 10, cutoff=8)
    if scale == "paper":
        return "fft-paper", bots.make("fft", "paper")
    return "fft-medium", bots.fft(n=1 << 15, cutoff=4)


def sweep(machine: Machine, wl, *, axes, threads: int, seeds, span: float,
          workers=None, store=None, timeout=None, retry=None):
    """Yield one row per (fault kind, intensity, scheduler): mean
    makespan over seeds, inflation vs the faults-off baseline, and the
    fault accounting. ``workers`` sets the batch pool size (None:
    resolve from REPRO_SIM_WORKERS / cpu count); ``store`` journals
    every completed cell so an interrupted campaign resumes (pass
    ``--store`` on the CLI); ``timeout``/``retry`` engage the
    kill-capable supervisor (see :func:`repro.core.sim.run_sweep`)."""
    kw = dict(workers=workers, store=store, timeout=timeout, retry=retry)
    master = machine.context(threads).thread_cores[0]
    base = machine.grid(workloads=[wl], schedulers=SCHEDULERS,
                        threads=threads, seeds=seeds)
    base_res = base.run(strict=False, **kw)
    baseline = {}
    for k, r in base_res.items():
        if isinstance(r, CellError):
            continue
        baseline.setdefault(k.scheduler, []).append(r.makespan)

    for kind, xs in axes.items():
        for x in xs:
            spec = _specs(kind, x, master, span)
            grid = machine.grid(workloads=[wl], schedulers=SCHEDULERS,
                                threads=threads, seeds=seeds,
                                faults=[spec])
            res = grid.run(strict=False, **kw)
            per_sched: dict = {}
            for k, r in res.items():
                per_sched.setdefault(k.scheduler, []).append(r)
            for sched in SCHEDULERS:
                cells = per_sched.get(sched, [])
                errs = [c for c in cells if isinstance(c, CellError)]
                ok = [c for c in cells if not isinstance(c, CellError)]
                if not ok:
                    yield dict(kind=kind, intensity=x, spec=spec,
                               scheduler=sched, failed_cells=len(errs),
                               error=str(errs[0].error) if errs else "")
                    continue
                mk = sum(r.makespan for r in ok) / len(ok)
                b = sum(baseline[sched]) / len(baseline[sched])
                yield dict(
                    kind=kind, intensity=x, spec=spec, scheduler=sched,
                    makespan=round(mk, 4), baseline=round(b, 4),
                    inflation=round(mk / b, 4),
                    reclaimed=sum(r.reclaimed for r in ok),
                    reexec=sum(r.reexec for r in ok),
                    fault_lost=round(sum(r.fault_lost for r in ok), 4),
                    failed_cells=len(errs))


def trace_forensics(machine: Machine, wl, threads: int, seeds,
                    workers=None) -> "list[dict]":
    """Faults-off execution forensics per scheduler (``--trace``).

    Runs the healthy baseline grid with event tracing and folds each
    cell through :mod:`analysis.stats` — steal volume and hop
    distances, per-node locality, thread utilization — the denominator
    story behind the inflation table above it.
    """
    from analysis import from_grid, stats
    grid = machine.grid(workloads=[wl], schedulers=SCHEDULERS,
                        threads=threads, seeds=seeds)
    rows = []
    for rec in from_grid(grid.run(workers=workers)):
        row = dict(label=rec.label)
        row.update(stats.summary(rec))
        rows.append(row)
    return rows


def _parity_check(machine: Machine, wl, threads: int, span: float) -> int:
    """--quick gate: every fault kind must be bit-identical py vs C."""
    if _csim.load() is None:
        print("# parity check skipped: C kernel unavailable "
              f"({_csim.load_error})")
        return 0
    master = machine.context(threads).thread_cores[0]
    bad = 0
    for kind, xs in QUICK_AXES.items():
        spec = _specs(kind, xs[0], master, span)
        out = {}
        for eng in ("py", "c"):
            os.environ["REPRO_SIM_ENGINE"] = eng
            reset_engine_cache()
            g = machine.grid(workloads=[wl], schedulers=SCHEDULERS,
                             threads=threads, faults=[spec])
            out[eng] = list(g.run().values())
        os.environ.pop("REPRO_SIM_ENGINE", None)
        reset_engine_cache()
        if out["py"] != out["c"]:
            bad += 1
            print(f"PARITY FAILURE under {spec!r}: py != c",
                  file=sys.stderr)
    print(f"# parity: {len(QUICK_AXES)} fault kinds x "
          f"{len(SCHEDULERS)} schedulers, {bad} divergence(s)")
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fft-small, 1 seed, trimmed axes, "
                         "py<->C parity assertion")
    ap.add_argument("--scale", choices=("medium", "paper"),
                    default="medium")
    ap.add_argument("--threads", type=int, default=16)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--workers", type=int, default=None,
                    help="batch worker pool size (default: "
                         "REPRO_SIM_WORKERS, then cpu count)")
    ap.add_argument("--store", default=None,
                    help="durable-sweep journal (JSONL): completed cells "
                         "are committed as they finish and replayed on "
                         "re-run, so an interrupted campaign resumes")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-cell wall-clock timeout in seconds "
                         "(default: REPRO_SIM_TIMEOUT); enables the "
                         "kill-capable supervised pool")
    ap.add_argument("--retries", type=int, default=None,
                    help="retry transient cell failures up to N times "
                         "with backoff, degrading C->py")
    ap.add_argument("--trace", action="store_true",
                    help="run with event tracing and append a faults-"
                         "off forensics table (steals, hop distances, "
                         "locality, utilization per scheduler)")
    ap.add_argument("--out", default=None,
                    help="write rows as JSON (default: stdout only)")
    args = ap.parse_args()

    machine = Machine(topology.sunfire_x4600(),
                      SimParams(trace=args.trace))
    name, wl = _workload(args.quick, args.scale)
    axes = QUICK_AXES if args.quick else AXES
    seeds = tuple(range(1 if args.quick else args.seeds))
    # fault horizon ~ the healthy makespan scale, so windows land
    # inside the run for small and paper workloads alike
    probe = machine.run(wl, "wf", threads=args.threads)
    span = max(probe.makespan / 2, 1.0)

    store = None
    if args.store:
        from repro.core.sim import ResultStore
        store = ResultStore(args.store)
    retry = None
    if args.retries is not None:
        from repro.core.sim import RetryPolicy
        retry = RetryPolicy(retries=args.retries)

    t0 = time.perf_counter()
    rows = []
    print("kind,intensity,scheduler,makespan,baseline,inflation,"
          "reclaimed,reexec,fault_lost,failed_cells")
    for row in sweep(machine, wl, axes=axes, threads=args.threads,
                     seeds=seeds, span=span, workers=args.workers,
                     store=store, timeout=args.timeout, retry=retry):
        rows.append(row)
        if "makespan" in row:
            print(f"{row['kind']},{row['intensity']},{row['scheduler']},"
                  f"{row['makespan']:.2f},{row['baseline']:.2f},"
                  f"{row['inflation']:.4f},{row['reclaimed']},"
                  f"{row['reexec']},{row['fault_lost']:.2f},"
                  f"{row['failed_cells']}", flush=True)
        else:
            print(f"{row['kind']},{row['intensity']},{row['scheduler']},"
                  f"FAILED,,,,,,{row['failed_cells']}", flush=True)
    dt = time.perf_counter() - t0
    print(f"# {len(rows)} rows ({name}, T={args.threads}, "
          f"seeds={len(seeds)}) in {dt:.1f}s")
    if store is not None:
        print(f"# store: {store!r}")
        store.close()

    forensics = None
    if args.trace:
        forensics = trace_forensics(machine, wl, args.threads, seeds,
                                    workers=args.workers)
        print("label,steals,steal_hop_mean,locality,util_mean,makespan")
        for row in forensics:
            print(f"{row['label']},{row['steals']},"
                  f"{row['steal_hop_mean']},{row['locality']},"
                  f"{row['util_mean']},{row['makespan']}", flush=True)

    bad = _parity_check(machine, wl, args.threads, span) if args.quick \
        else 0

    if args.out:
        with open(args.out, "w") as f:
            json.dump(dict(workload=name, threads=args.threads,
                           seeds=len(seeds), span=span, rows=rows,
                           forensics=forensics),
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.out}")
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
