"""Tests for the parallel Monte-Carlo batch engine.

The tentpole contract: a batch dispatched across the worker pool — C
pthreads inside ``sim_run_batch``, a fork process pool around the py
engine — is bit-identical per (cell, seed) to serial dispatch at any
worker count; the seed axis aggregates into exact :class:`CellStats`;
and a failing cell inside a worker surfaces as the same labeled
:class:`CellError` as on the serial path.
"""

import math
import os
import pickle

import pytest

from repro.core import topology
from repro.core.sim import (CellError, CellStats, Machine, SimParams,
                            SimResult, SimStalled, Stat, aggregate, bots,
                            reset_engine_cache, resolve_workers)
from repro.core.sim import _csim, _engine_py

TOPO = topology.sunfire_x4600()
HAVE_C = _csim.load() is not None
ENGINES = ["py", "c"] if HAVE_C else ["py"]
NCPU = os.cpu_count() or 1


@pytest.fixture(params=ENGINES)
def engine(request, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", request.param)
    reset_engine_cache()
    yield request.param
    reset_engine_cache()


def _grid(machine, wl, **kw):
    """A grid that exercises rng-dependent paths: stealing, migration,
    and every fault kind, across thread counts and seeds."""
    kw.setdefault("faults", [None, "straggler:1.0", "preempt:2@50",
                             "fail:2@100"])
    return machine.grid(
        workloads=[wl], schedulers=("wf", "dfwsrpt", "bf"),
        threads=(4, 16), seeds=3, migration_rate=0.1, **kw)


# ----------------------------------------------------------------------
# determinism: workers ∈ {1, 2, cpu_count} bit-identical per cell
# ----------------------------------------------------------------------

def test_workers_bit_identical(engine):
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = _grid(machine, wl)
    base = grid.run(workers=1)
    assert len(base) == 3 * 2 * 3 * 4
    for w in sorted({2, 4, NCPU}):
        res = grid.run(workers=w)
        assert res == base, f"workers={w} diverged on {engine}"


def test_workers_default_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SIM_WORKERS", raising=False)
    assert resolve_workers(8) == 8
    assert resolve_workers(0) == 1          # explicit floor
    assert resolve_workers(None, SimParams(workers=6)) == 6
    monkeypatch.setenv("REPRO_SIM_WORKERS", "5")
    assert resolve_workers() == 5
    assert resolve_workers(2) == 2          # explicit beats env
    assert resolve_workers(None, SimParams(workers=3)) == 3
    monkeypatch.setenv("REPRO_SIM_WORKERS", "nope")
    with pytest.raises(ValueError, match="REPRO_SIM_WORKERS"):
        resolve_workers()
    monkeypatch.delenv("REPRO_SIM_WORKERS")
    assert resolve_workers() == (os.cpu_count() or 1)


def test_workers_env_applies_to_grid(engine, monkeypatch):
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = machine.grid(workloads=[wl], schedulers=("wf",), threads=16,
                        seeds=2)
    base = grid.run(workers=1)
    monkeypatch.setenv("REPRO_SIM_WORKERS", "2")
    assert grid.run() == base


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_c_kernel_reports_thread_support():
    assert _csim.load() is not None
    # the default toolchain here built with -pthread; either way the
    # flag and the exported probe must agree
    assert _csim.threads_supported == bool(
        _csim.load().sim_threads_available())


# ----------------------------------------------------------------------
# CellStats aggregation: exact math
# ----------------------------------------------------------------------

def _res(makespan):
    return SimResult(makespan=makespan, serial_time=10.0,
                     speedup=10.0 / makespan, tasks=1, steals=2,
                     failed_probes=0, remote_work_fraction=0.0,
                     queue_wait=0.0)


def test_cellstats_exact_mean_ci95():
    cs = aggregate([_res(m) for m in (1.0, 2.0, 3.0, 4.0)])
    assert isinstance(cs, CellStats)
    assert cs.n == 4
    assert cs.makespan.mean == 2.5
    assert cs.makespan.min == 1.0 and cs.makespan.max == 4.0
    # sample std: sum((x-2.5)^2) = 5.0, ddof=1 -> sqrt(5/3)
    assert cs.makespan.std == pytest.approx(math.sqrt(5.0 / 3.0), abs=0,
                                            rel=1e-15)
    assert cs.makespan.ci95 == pytest.approx(
        1.96 * math.sqrt(5.0 / 3.0) / 2.0, rel=1e-15)
    assert cs.steals.mean == 2.0 and cs.steals.std == 0.0
    assert len(cs.results) == 4 and cs.errors == ()


def test_cellstats_single_and_empty():
    one = aggregate([_res(5.0)])
    assert one.n == 1
    assert one.makespan == Stat(5.0, 0.0, 5.0, 5.0, 0.0)
    none = aggregate([CellError("cell", 0, ValueError("x"))])
    assert none.n == 0
    assert math.isnan(none.makespan.mean)
    assert len(none.errors) == 1


def test_run_stats_groups_by_seedless_key(engine):
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = machine.grid(workloads=[wl], schedulers=("wf", "dfwsrpt"),
                        threads=16, seeds=4)
    raw = grid.run()
    stats = grid.run_stats(workers=2)
    assert len(stats) == 2
    for k, cs in stats.items():
        assert k.seed is None
        assert cs.n == 4
        mks = [r.makespan for kk, r in raw.items()
               if kk._replace(seed=None) == k]
        assert cs.makespan.mean == pytest.approx(
            math.fsum(mks) / 4, rel=1e-15)
        assert [r.makespan for r in cs.results] == mks


# ----------------------------------------------------------------------
# strict=False isolation through the parallel paths
# ----------------------------------------------------------------------

def test_stall_isolated_at_any_worker_count(engine):
    machine = Machine(TOPO, SimParams(max_steps=5))
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = machine.grid(workloads=[wl], schedulers=("wf",), threads=16,
                        seeds=2)
    for w in (1, 2):
        res = grid.run(strict=False, workers=w)
        assert all(isinstance(r, CellError) for r in res.values())
        err = next(iter(res.values()))
        assert isinstance(err.error, SimStalled)
        assert err.label.startswith("grid cell (fft/wf/")
        with pytest.raises(SimStalled, match="grid cell"):
            grid.run(strict=True, workers=w)


def test_py_pool_isolates_engine_exception(monkeypatch):
    """A cell raising inside a forked py worker comes back as the same
    labeled CellError as on the serial path, without poisoning the rest
    of the batch."""
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    orig = _engine_py.run

    def boom(ctx):
        if ctx["seed"] == 1:
            raise ValueError("injected failure")
        return orig(ctx)

    monkeypatch.setattr(_engine_py, "run", boom)
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = machine.grid(workloads=[wl], schedulers=("wf",), threads=16,
                        seeds=3)
    for w in (1, 2):   # fork children inherit the monkeypatch
        res = list(grid.run(strict=False, workers=w).items())
        assert isinstance(res[0][1], SimResult)
        assert isinstance(res[2][1], SimResult)
        k, err = res[1]
        assert k.seed == 1
        assert isinstance(err, CellError)
        assert isinstance(err.error, ValueError)
        assert "injected failure" in str(err.error)
        assert "seed=1" in err.label
    reset_engine_cache()


def test_py_pool_flattens_unpicklable_exception(monkeypatch):
    """An exception that can't round-trip the pool's result pickle is
    flattened to a RuntimeError carrying type and message."""
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()

    class Unpicklable(Exception):
        def __init__(self, msg):
            super().__init__(msg)
            self.fh = open(os.devnull)      # defeats pickle

    def boom(ctx):
        raise Unpicklable("cannot cross the pool")

    monkeypatch.setattr(_engine_py, "run", boom)
    with pytest.raises(Exception):
        pickle.dumps(Unpicklable("x"))
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = machine.grid(workloads=[wl], schedulers=("wf",), threads=16,
                        seeds=2)
    res = grid.run(strict=False, workers=2)
    for err in res.values():
        assert isinstance(err, CellError)
        assert isinstance(err.error, (RuntimeError, Unpicklable))
        assert "cannot cross the pool" in str(err.error)
    reset_engine_cache()


def test_run_batch_returns_per_cell_slots(engine):
    """Both engines' run_batch return one entry per context, in order,
    each a result dict or an exception object (never a raise that
    poisons the batch)."""
    from repro.core.sim import policy
    from repro.core.sim.runtime import _prepare_ctx
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    ectx = machine.context(16)
    spec = policy.get_spec("wf")
    ctxs = [_prepare_ctx(ectx, wl, spec, seed) for seed in (0, 1, 2)]
    mod = _csim if engine == "c" else _engine_py
    outs = mod.run_batch(ctxs, workers=2)
    assert len(outs) == 3
    assert all(isinstance(o, dict) and "makespan" in o for o in outs)
    serial = [mod.run_batch([_prepare_ctx(ectx, wl, spec, s)])[0]
              for s in (0, 1, 2)]
    assert outs == serial


# ----------------------------------------------------------------------
# graceful degradation
# ----------------------------------------------------------------------

def test_py_pool_failure_falls_back_serial(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    import multiprocessing as mp

    def no_ctx(method=None):
        raise ValueError("fork unavailable")

    monkeypatch.setattr(mp, "get_context", no_ctx)
    monkeypatch.setattr(_engine_py, "_warned_no_pool", False)
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = machine.grid(workloads=[wl], schedulers=("wf",), threads=16,
                        seeds=2)
    base = grid.run(workers=1)
    with pytest.warns(RuntimeWarning, match="multiprocessing pool"):
        assert grid.run(workers=2) == base
    # warning fires once
    res = grid.run(workers=2)
    assert res == base
    reset_engine_cache()


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_c_no_threads_build_falls_back_serial(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
    reset_engine_cache()
    machine = Machine(TOPO)
    wl = bots.fft(n=1 << 10, cutoff=8)
    grid = machine.grid(workloads=[wl], schedulers=("wf",), threads=16,
                        seeds=2)
    base = grid.run(workers=1)
    monkeypatch.setattr(_csim, "threads_supported", False)
    monkeypatch.setattr(_csim, "_warned_no_threads", False)
    with pytest.warns(RuntimeWarning, match="without pthread"):
        assert grid.run(workers=2) == base
    reset_engine_cache()
