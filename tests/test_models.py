"""Per-arch smoke tests (reduced configs): one train step + one
prefill/decode consistency pass on CPU, asserting shapes and finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model

ARCHS = list(configs.ARCHS)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    b = {"labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.embeds_input:
        b["embeds"] = jax.random.normal(k, (B, S, cfg.d_model),
                                        dtype=jnp.float32)
    else:
        b["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    if cfg.num_media_tokens:
        b["media"] = jax.random.normal(
            k, (B, cfg.num_media_tokens, cfg.d_model))
    return b


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get(arch).reduced()
    params = model.init_params(cfg, KEY)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.train_loss(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["ce"]))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = configs.get(arch).reduced()
    params = model.init_params(cfg, KEY)
    b = _batch(cfg, B=2, S=8)
    logits, aux = model.forward(params, cfg, tokens=b.get("tokens"),
                                embeds=b.get("embeds"),
                                media=b.get("media"))
    assert logits.shape == (2, 8, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.slow
@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not configs.get(a).is_encoder])
def test_prefill_decode_consistency(arch):
    cfg = configs.get(arch).reduced()
    if cfg.moe_num_experts:
        # ample capacity ⇒ routing independent of token grouping
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.moe_num_experts))
    params = model.init_params(cfg, KEY)
    B, S = 2, 12
    b = _batch(cfg, B=B, S=S + 1, seed=3)
    tokens = b["tokens"]
    media = b.get("media")
    full, _ = model.forward(params, cfg, tokens=tokens, media=media)
    last, caches = model.prefill(params, cfg, tokens=tokens[:, :S],
                                 media=media, max_len=S + 4)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, S - 1]),
                               rtol=3e-3, atol=3e-3)
    dl, caches = model.decode_step(params, cfg, caches, tokens[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(dl[:, 0]),
                               np.asarray(full[:, S]),
                               rtol=3e-3, atol=3e-3)
    assert int(caches["length"]) == S + 1


def test_encoder_has_bidirectional_attention():
    """hubert forward must differ from a causal run of the same weights."""
    cfg = configs.get("hubert-xlarge").reduced()
    params = model.init_params(cfg, KEY)
    b = _batch(cfg, B=1, S=8)
    out1, _ = model.forward(params, cfg, embeds=b["embeds"])
    causal_cfg = dataclasses.replace(cfg, is_encoder=False)
    out2, _ = model.forward(params, causal_cfg, embeds=b["embeds"])
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]),
                           atol=1e-5)


def test_label_masking():
    cfg = configs.get("stablelm-1.6b").reduced()
    params = model.init_params(cfg, KEY)
    b = _batch(cfg, B=2, S=8)
    l_all, _ = model.train_loss(params, cfg, b)
    b2 = dict(b, labels=b["labels"].at[0].set(-100))
    l_masked, _ = model.train_loss(params, cfg, b2)
    assert not np.isclose(float(l_all), float(l_masked))


def test_param_counts_match_published_sizes():
    """Full configs: derived param counts sit near the advertised sizes."""
    expected = {
        "qwen2.5-3b": (2.5e9, 3.6e9),
        "qwen3-14b": (13e9, 15.5e9),
        "command-r-35b": (28e9, 38e9),
        "llama-3.2-vision-90b": (80e9, 95e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "mamba2-1.3b": (1.1e9, 1.5e9),
        "granite-moe-1b-a400m": (1.0e9, 1.6e9),
        "llama4-scout-17b-a16e": (95e9, 118e9),
        "stablelm-1.6b": (1.4e9, 1.9e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for arch, (lo, hi) in expected.items():
        n = model.param_count(configs.get(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    cfg = configs.get("granite-moe-1b-a400m")
    total = model.param_count(cfg)
    active = model.active_param_count(cfg)
    assert active < total
    assert 0.25e9 < active < 0.65e9     # “a400m” ≈ 0.4B active


def test_kv_repeat_equivalence():
    """kv_repeat is a layout change only — logits must be identical."""
    cfg = configs.get("command-r-35b").reduced()
    params = model.init_params(cfg, KEY)
    b = _batch(cfg, B=1, S=8)
    out1, _ = model.forward(params, cfg, tokens=b["tokens"])
    cfg2 = dataclasses.replace(cfg, kv_repeat=2)
    out2, _ = model.forward(params, cfg2, tokens=b["tokens"])
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)
