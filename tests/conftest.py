import os
import tempfile

import pytest


@pytest.fixture(scope="session", autouse=True)
def _hermetic_compile_cache():
    """Point the persistent compile cache at a per-session temp dir.

    Test runs must neither read nor pollute the user's
    ``~/.cache/repro-sim`` (a stale artifact there could mask a bug; a
    test-built one could leak out). An explicitly exported
    ``REPRO_SIM_CACHE`` — including ``0`` — is honored as-is.
    """
    if "REPRO_SIM_CACHE" in os.environ:
        yield
        return
    with tempfile.TemporaryDirectory(prefix="repro-sim-tests-") as tmp:
        os.environ["REPRO_SIM_CACHE"] = tmp
        from repro.core.sim import reset_cache
        reset_cache()
        try:
            yield
        finally:
            os.environ.pop("REPRO_SIM_CACHE", None)
            reset_cache()
