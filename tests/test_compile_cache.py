"""Persistent compile-cache correctness (see sim/compile_cache.py).

Covers the ISSUE-9 contract: key sensitivity (any input that changes
the computation must miss), corruption tolerance (torn/scribbled
artifacts rebuild with a one-time warning, never wrong results),
mmap-restored tables bit-identical to freshly built ones on both
engines, clean ``REPRO_SIM_CACHE=0`` bypass, and the concurrent-build
hardening of the ``_csim`` shared object.
"""

from __future__ import annotations

import glob
import json
import os
import subprocess

import numpy as np
import pytest

from repro.core import topology
from repro.core.sim import (Machine, SimParams, bots, compile_cache,
                            get_cache, reset_cache, reset_engine_cache)
from repro.core.sim import _csim
from repro.core.sim.runtime import Workload, ensure_table, serial_time
from repro.core.sim.table import TaskTable


@pytest.fixture()
def cache_root(tmp_path, monkeypatch):
    """A fresh cache root per test (and a clean handle)."""
    root = tmp_path / "cache"
    monkeypatch.setenv("REPRO_SIM_CACHE", str(root))
    reset_cache()
    yield str(root)
    reset_cache()


def _engines():
    return ["py"] if _csim.load() is None else ["py", "c"]


def _use_engine(monkeypatch, name):
    monkeypatch.setenv("REPRO_SIM_ENGINE", name)
    reset_engine_cache()


# ----------------------------------------------------------------------
# key sensitivity
# ----------------------------------------------------------------------

def test_workload_key_sensitivity():
    k = bots.workload_cache_key
    assert k("fft", "medium") == k("fft", "medium")
    assert k("fft", "medium") != k("fft", "large")
    assert k("fft", "medium") != k("sort", "medium")


def test_workload_key_tracks_builder_source(monkeypatch):
    base = bots.workload_cache_key("fft", "medium")
    monkeypatch.setattr(compile_cache, "source_fingerprint",
                        lambda *m: "edited-builder-source")
    assert bots.workload_cache_key("fft", "medium") != base


def _serial_keys(root):
    d = os.path.join(root, "serial")
    return set(os.listdir(d)) if os.path.isdir(d) else set()


def test_serial_key_sensitivity(cache_root):
    """Changing topology, workload, µ, or λ each mints a new artifact."""
    wl = bots.fft(n=1 << 8, cutoff=4)
    topo = topology.sunfire_x4600()
    n0 = len(_serial_keys(cache_root))
    serial_time(topo, wl, 0, None, SimParams())
    assert len(_serial_keys(cache_root)) == n0 + 1
    # different topology (fresh table so the in-memory per-table cache
    # can't short-circuit; content-equal table → same table fingerprint,
    # different topology fingerprint must still miss)
    serial_time(topology.uma(16), bots.fft(n=1 << 8, cutoff=4), 0, None,
                SimParams())
    assert len(_serial_keys(cache_root)) == n0 + 2
    # different µ (same table)
    wl_mu = Workload(wl.name, wl.root, wl.mem_intensity * 2.0,
                     table=ensure_table(wl))
    serial_time(topo, wl_mu, 0, None, SimParams())
    assert len(_serial_keys(cache_root)) == n0 + 3
    # different λ
    serial_time(topo, wl, 0, None, SimParams(hop_lambda=0.7))
    assert len(_serial_keys(cache_root)) == n0 + 4
    # different table
    serial_time(topo, bots.sort(n=1 << 8, cutoff=4), 0, None, SimParams())
    assert len(_serial_keys(cache_root)) == n0 + 5
    # replaying any of them is a pure hit — no new artifacts
    serial_time(topology.sunfire_x4600(), bots.fft(n=1 << 8, cutoff=4),
                0, None, SimParams())
    assert len(_serial_keys(cache_root)) == n0 + 5


# ----------------------------------------------------------------------
# round-trips
# ----------------------------------------------------------------------

def test_make_round_trip_is_mmap_backed_and_identical(cache_root):
    built = bots.make("fft", "medium")        # miss → build + store
    restored = bots.make("fft", "medium")     # hit → mmap restore
    assert built is not restored
    assert restored.root is None
    t0, t1 = ensure_table(built), ensure_table(restored)
    assert isinstance(t1.work_pre, np.memmap)
    assert not t1.work_pre.flags["WRITEABLE"]
    assert t1.fingerprint() == t0.fingerprint()
    for name in TaskTable.ARRAY_FIELDS:
        np.testing.assert_array_equal(getattr(t0, name),
                                      getattr(t1, name))
    assert get_cache().hit_count("tables") == 1


def test_serial_value_round_trips_exactly(cache_root):
    wl = bots.fft(n=1 << 10, cutoff=8)
    topo = topology.sunfire_x4600()
    fresh = serial_time(topo, wl, 0, None, SimParams())
    # same inputs, fresh in-memory state → the persisted value, bit-exact
    wl2 = bots.fft(n=1 << 10, cutoff=8)
    replayed = serial_time(topo, wl2, 0, None, SimParams())
    assert replayed == fresh
    assert get_cache().hit_count("serial") == 1


def test_context_and_victim_plan_round_trip(cache_root):
    m1 = Machine(topology.sunfire_x4600())
    r1 = m1.run(bots.fft(n=1 << 10, cutoff=8), "dfwsrpt", seed=0,
                threads=16, binding="paper", placement="spill:2")
    # a fresh, equal-content topology (new object → empty lazy caches)
    # must hit the persisted binding/placement/victim-plan artifacts
    reset_cache()
    m2 = Machine(topology.sunfire_x4600())
    r2 = m2.run(bots.fft(n=1 << 10, cutoff=8), "dfwsrpt", seed=0,
                threads=16, binding="paper", placement="spill:2")
    assert r1 == r2
    stats = get_cache().stats()
    assert stats["hits"].get("contexts") and stats["hits"].get("plans")
    assert stats["corrupt"] == {}


def test_mmap_tables_bit_identical_on_both_engines(cache_root,
                                                   monkeypatch):
    bots.make("fft", "medium")                 # populate
    restored = bots.make("fft", "medium")      # mmap-backed hit
    assert isinstance(ensure_table(restored).work_pre, np.memmap)
    monkeypatch.setenv("REPRO_SIM_CACHE", "0")  # fresh build, no cache
    reset_cache()
    fresh = bots.make("fft", "medium")
    assert not isinstance(ensure_table(fresh).work_pre, np.memmap)
    for eng in _engines():
        _use_engine(monkeypatch, eng)
        m = Machine(topology.sunfire_x4600())
        r_fresh = m.run(fresh, "dfwsrpt", seed=3, threads=16,
                        binding="paper", placement="spill:2")
        r_mmap = m.run(restored, "dfwsrpt", seed=3, threads=16,
                       binding="paper", placement="spill:2")
        assert r_fresh == r_mmap, eng
    reset_engine_cache()


# ----------------------------------------------------------------------
# corruption tolerance
# ----------------------------------------------------------------------

def test_torn_table_artifact_rebuilds_with_warning(cache_root):
    bots.make("fft", "medium")
    expected = ensure_table(bots.make("fft", "medium"))
    blobs = glob.glob(os.path.join(cache_root, "tables", "*", "*.npy"))
    assert blobs
    with open(blobs[0], "r+b") as f:           # tear: truncate mid-data
        f.truncate(os.path.getsize(blobs[0]) // 2)
    reset_cache()
    with pytest.warns(RuntimeWarning, match="compile cache"):
        rebuilt = bots.make("fft", "medium")
    tbl = ensure_table(rebuilt)
    assert tbl.fingerprint() == expected.fingerprint()
    stats = get_cache().stats()
    assert stats["corrupt"].get("tables") == 1
    # the artifact was re-stored: next consult is a clean hit
    assert ensure_table(bots.make("fft", "medium")).fingerprint() \
        == expected.fingerprint()
    assert get_cache().stats()["corrupt"].get("tables") == 1


def test_scribbled_manifest_rebuilds(cache_root):
    bots.make("fft", "medium")
    manifests = glob.glob(os.path.join(cache_root, "tables", "*",
                                       "manifest.json"))
    assert manifests
    with open(manifests[0], "w") as f:
        f.write('{"format": "repro-sim-compile-cache", "version": 1, '
                '"payload": {"arrays": {}, "meta": {}}, '
                '"checksum": "0000"}')
    reset_cache()
    with pytest.warns(RuntimeWarning, match="checksum"):
        wl = bots.make("fft", "medium")
    assert ensure_table(wl).n > 0


def test_corrupt_serial_artifact_rebuilds(cache_root):
    wl = bots.fft(n=1 << 10, cutoff=8)
    topo = topology.sunfire_x4600()
    fresh = serial_time(topo, wl, 0, None, SimParams())
    files = glob.glob(os.path.join(cache_root, "serial", "*.json"))
    assert files
    with open(files[0], "w") as f:
        f.write("{ torn json")
    reset_cache()
    with pytest.warns(RuntimeWarning, match="compile cache"):
        replayed = serial_time(topo, bots.fft(n=1 << 10, cutoff=8), 0,
                               None, SimParams())
    assert replayed == fresh


def test_version_mismatch_is_discarded(cache_root):
    cache = get_cache()
    cache.put_json("serial", "k1", {"serial": 1.5})
    path = cache._json_path("serial", "k1")
    with open(path) as f:
        doc = json.load(f)
    doc["version"] = 999
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.warns(RuntimeWarning, match="version mismatch"):
        assert cache.get_serial("k1") is None
    # discarded on disk → a fresh put works and hits again
    cache.put_serial("k1", 2.5)
    assert cache.get_serial("k1") == 2.5


# ----------------------------------------------------------------------
# disable switch
# ----------------------------------------------------------------------

def test_cache_disabled_bypasses_cleanly(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_CACHE", "0")
    reset_cache()
    assert get_cache() is None
    assert compile_cache.cache_root() is None
    wl = bots.make("fft", "medium")
    assert not isinstance(ensure_table(wl).work_pre, np.memmap)
    r = Machine(topology.sunfire_x4600()).run(
        wl, "wf", seed=0, threads=8, binding="paper")
    assert r.makespan > 0
    reset_cache()


def test_env_change_re_resolves_handle(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_CACHE", str(tmp_path / "a"))
    c1 = get_cache()
    monkeypatch.setenv("REPRO_SIM_CACHE", str(tmp_path / "b"))
    c2 = get_cache()
    assert c1 is not c2 and c1.root != c2.root
    monkeypatch.setenv("REPRO_SIM_CACHE", "0")
    assert get_cache() is None
    reset_cache()


# ----------------------------------------------------------------------
# _csim artifact hardening
# ----------------------------------------------------------------------

def test_csim_artifact_reused_without_compiler(cache_root, monkeypatch):
    if _csim.load() is None:
        pytest.skip("no C toolchain")
    _csim.reset()
    try:
        assert _csim.load() is not None
        assert _csim.compiled_this_process   # fresh root → real compile
        so = glob.glob(os.path.join(cache_root, "csim", "csim_*.so"))
        assert so, "kernel not placed under the cache root"
        # a second load in the same toolchain state must dlopen the
        # cached artifact without ever invoking the compiler
        _csim.reset()

        def _no_compiles(*a, **k):
            raise AssertionError("compiler invoked on a warm cache")

        monkeypatch.setattr(subprocess, "run", _no_compiles)
        assert _csim.load() is not None
        assert not _csim.compiled_this_process
    finally:
        monkeypatch.undo()
        _csim.reset()
        _csim.load()


def test_csim_loser_reuses_winners_artifact(cache_root, monkeypatch):
    if _csim.load() is None:
        pytest.skip("no C toolchain")
    _csim.reset()
    try:
        assert _csim.load() is not None      # publish the artifact
        _csim.reset()
        real_run = subprocess.run

        def _losing_compile(cmd, *a, **k):
            if any(str(c).endswith("_csim.c") for c in cmd):
                # simulate losing the build race: our compile dies, but
                # the winner's artifact is already on the keyed path
                raise subprocess.CalledProcessError(1, cmd)
            return real_run(cmd, *a, **k)

        monkeypatch.setattr(subprocess, "run", _losing_compile)
        assert _csim.load() is not None
        assert not _csim.compiled_this_process
    finally:
        monkeypatch.undo()
        _csim.reset()
        _csim.load()


def test_csim_tempdir_fallback_when_disabled(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_CACHE", "0")
    reset_cache()
    d = _csim._csim_dir()
    assert "repro-sim-csim-" in d and os.path.isdir(d)
    reset_cache()


# ----------------------------------------------------------------------
# raw artifact layer
# ----------------------------------------------------------------------

def test_put_get_arrays_verifies_structure(cache_root):
    cache = get_cache()
    arrays = dict(a=np.arange(5, dtype=np.int64),
                  b=np.linspace(0, 1, 5))
    cache.put_arrays("tables", "k", arrays, {"note": "x"})
    got, meta = cache.get_arrays("tables", "k")
    assert meta == {"note": "x"}
    np.testing.assert_array_equal(got["a"], arrays["a"])
    np.testing.assert_array_equal(got["b"], arrays["b"])
    # scribble one blob's bytes (size/dtype/shape intact): the data
    # checksum catches it (small artifact → eager verification)
    path = os.path.join(cache_root, "tables", "k", "a.npy")
    blob = np.load(path)
    blob[0] = 999
    with open(path, "wb") as f:
        np.save(f, blob)
    with pytest.warns(RuntimeWarning, match="data checksum"):
        assert cache.get_arrays("tables", "k") is None


def test_repeated_puts_are_safe(cache_root):
    """Racing/repeated writers under one key never corrupt an artifact
    (equal keys hold equal content by construction)."""
    cache = get_cache()
    cache.put_json("serial", "k", {"serial": 1.0})
    cache.put_json("serial", "k", {"serial": 1.0})
    assert cache.get_serial("k") == 1.0
    a1 = dict(x=np.arange(3, dtype=np.int64))
    cache.put_arrays("tables", "k2", a1, {})
    cache.put_arrays("tables", "k2", dict(x=np.arange(3, dtype=np.int64)),
                     {})                               # first write wins
    got, _ = cache.get_arrays("tables", "k2")
    np.testing.assert_array_equal(got["x"], a1["x"])
