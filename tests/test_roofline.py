"""Properties of the sharding rules engine and the roofline model, plus
dry-run artifact integrity (when artifacts are present)."""

import glob
import json
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.shardings import fit_spec

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@settings(max_examples=40, deadline=None)
@given(
    d0=st.integers(1, 4096), d1=st.integers(1, 4096),
    a0=st.sampled_from([None, "data", "model", ("data", "model")]),
    a1=st.sampled_from([None, "data", "model"]),
    data=st.sampled_from([2, 4, 16]), model=st.sampled_from([2, 8, 16]),
)
def test_fit_spec_always_divides(d0, d1, a0, a1, data, model):
    """Property: whatever fit_spec keeps divides its dimension."""
    mesh = _FakeMesh({"data": data, "model": model})
    p = fit_spec(mesh, (d0, d1), P(a0, a1))
    entries = tuple(p) + (None,) * (2 - len(tuple(p)))
    for dim, ax in zip((d0, d1), entries):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % total == 0


@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_analytic_terms_all_cells(mesh_kind):
    """Roofline terms are finite/positive and structurally sane for all
    31 runnable cells."""
    from benchmarks.roofline import analytic_terms
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for shape in cfg.shapes():
            a = analytic_terms(arch, shape, mesh_kind, micro=4)
            assert a["flops_dev"] > 0 and np.isfinite(a["flops_dev"])
            assert a["bytes_dev"] > 0 and np.isfinite(a["bytes_dev"])
            assert a["ici_bytes"] >= 0 and a["dci_bytes"] >= 0
            # total flops at least the useful model flops
            assert a["flops_dev"] >= a["model_flops_dev"] * 0.99
            kind = configs.SHAPES[shape].kind
            if kind == "decode":
                # decode must be memory-heavy relative to compute
                assert a["bytes_dev"] / 819e9 > a["flops_dev"] / 197e12
            if mesh_kind == "single":
                assert a["dci_bytes"] == 0


def test_train_flops_scale_with_tokens():
    from benchmarks.roofline import analytic_terms
    a1 = analytic_terms("qwen3-14b", "train_4k", "single", micro=4)
    a2 = analytic_terms("qwen3-14b", "prefill_32k", "single", micro=1)
    # train does fwd+bwd (+remat): ≥3× prefill per token; token counts
    # equal (256·4096 vs 32·32768)
    assert a1["flops_dev"] > 2.5 * a2["flops_dev"]


def test_microbatches_increase_gather_traffic():
    from benchmarks.roofline import analytic_terms
    lo = analytic_terms("command-r-35b", "train_4k", "single", micro=2)
    hi = analytic_terms("command-r-35b", "train_4k", "single", micro=16)
    assert hi["ici_bytes"] > lo["ici_bytes"]


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*.json")),
                    reason="dry-run artifacts not generated")
def test_dryrun_artifacts_complete_and_wellformed():
    """The 40-cell grid: 31 ok + 9 documented skips on both meshes."""
    seen = {}
    for p in glob.glob(os.path.join(ART, "*.json")):
        with open(p) as f:
            rec = json.load(f)
        seen[(rec["arch"], rec["shape"], rec["mesh"])] = rec["status"]
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for mesh in ("single", "multi"):
            for shape in cfg.shapes():
                assert seen.get((arch, shape, mesh)) == "ok", \
                    (arch, shape, mesh)
            for shape in cfg.skipped_shapes():
                assert seen.get((arch, shape, mesh)) in ("skipped", None)
    oks = [k for k, v in seen.items() if v == "ok"]
    assert len(oks) == 62      # 31 cells × 2 meshes


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*.json")),
                    reason="dry-run artifacts not generated")
def test_dryrun_ok_cells_have_cost_and_collectives():
    for p in glob.glob(os.path.join(ART, "*.json")):
        with open(p) as f:
            rec = json.load(f)
        if rec["status"] != "ok":
            continue
        assert rec["cost"]["flops_per_device"] is not None
        assert rec["memory"]["argument_bytes"] is not None
        assert isinstance(rec["collectives"], dict)
        if rec["kind"] == "train":
            # every training step must synchronize gradients somehow
            assert any(k in rec["collectives"]
                       for k in ("all-reduce", "reduce-scatter")), p
