"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
fault tolerance, placement."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.core import placement, topology
from repro.data import PipelineConfig, TokenPipeline
from repro.optim import (AdamWConfig, accumulate_gradients, adamw_init,
                         adamw_update, compressed_gradients, cosine_schedule,
                         global_norm)
from repro.runtime import (HeartbeatMonitor, Supervisor,
                           plan_elastic_remesh)


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------

def _pipe(gb=8, seq=32, seed=1, **kw):
    return TokenPipeline(PipelineConfig(vocab_size=1000, seq_len=seq,
                                        global_batch=gb, seed=seed, **kw))


def test_pipeline_deterministic_and_stateless():
    p = _pipe()
    a = p.batch_at(17)
    b = p.batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


@settings(max_examples=10, deadline=None)
@given(hosts=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 100))
def test_host_shards_concatenate(hosts, step):
    p = _pipe()
    full = p.batch_at(step)["tokens"]
    parts = [p.host_batch_at(step, h, hosts)["tokens"]
             for h in range(hosts)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_tokens_in_vocab_and_labels_masked():
    p = _pipe(seq=2048, gb=4)
    b = p.batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000
    assert (b["labels"] == -100).sum() > 0      # doc boundaries masked


def test_modality_stubs():
    p = TokenPipeline(PipelineConfig(vocab_size=504, seq_len=16,
                                     global_batch=2, embeds_dim=32,
                                     d_model=32))
    b = p.batch_at(0)
    assert "embeds" in b and b["embeds"].shape == (2, 16, 32)
    assert np.isfinite(b["embeds"]).all()


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------

def _toy():
    k = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(k, (16, 16)), "b": jnp.zeros((16,))}
    X = jax.random.normal(k, (64, 16))
    Y = X @ (jnp.eye(16) * 0.5) + 1.0
    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}
    return w, {"x": X, "y": Y}, loss_fn


def test_adamw_converges():
    w, batch, loss_fn = _toy()
    cfg = AdamWConfig(lr_peak=5e-2, warmup_steps=2, total_steps=300,
                      weight_decay=0.0)
    st_ = adamw_init(w, cfg)
    l0 = float(loss_fn(w, batch)[0])
    for _ in range(80):
        g = jax.grad(lambda p: loss_fn(p, batch)[0])(w)
        w, st_, _ = adamw_update(g, st_, w, cfg)
    assert float(loss_fn(w, batch)[0]) < 0.05 * l0


def test_factored_adamw_converges():
    w, batch, loss_fn = _toy()
    cfg = AdamWConfig(lr_peak=5e-2, warmup_steps=2, total_steps=300,
                      weight_decay=0.0, factored=True, m_dtype="bfloat16")
    st_ = adamw_init(w, cfg)
    assert isinstance(st_["v"]["w"], dict)       # factored on the matrix
    assert not isinstance(st_["v"]["b"], dict)   # vector stays full
    l0 = float(loss_fn(w, batch)[0])
    for _ in range(120):
        g = jax.grad(lambda p: loss_fn(p, batch)[0])(w)
        w, st_, _ = adamw_update(g, st_, w, cfg)
    assert float(loss_fn(w, batch)[0]) < 0.2 * l0


def test_accumulation_matches_full_batch():
    w, batch, loss_fn = _toy()
    _, g1, _ = accumulate_gradients(loss_fn, w, batch, 1)
    _, g4, _ = accumulate_gradients(loss_fn, w, batch, 4)
    np.testing.assert_allclose(g1["w"], g4["w"], rtol=1e-5, atol=1e-6)


def test_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, s)) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5e-3)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2] and lrs[4] == pytest.approx(1e-4, rel=0.01)


def test_compression_error_feedback_unbiased():
    """Error feedback: accumulated dequantized grads track true grads."""
    k = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(k, (128,)) * 1e-3}
    comp = None
    acc_true = np.zeros(128)
    acc_deq = np.zeros(128)
    for i in range(20):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        deq, comp = compressed_gradients(gi, comp)
        acc_true += np.asarray(gi["w"])
        acc_deq += np.asarray(deq["w"])
    # residual carried in comp.state bounds the cumulative error
    resid = np.abs(acc_true - acc_deq).max()
    one_step_err = float(jnp.abs(g["w"]).max()) / 127
    assert resid <= 3 * one_step_err


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------

def test_checkpoint_roundtrip_dtypes():
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "d": jnp.array(7, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, tree)
        got = restore(d, 3, tree)
        np.testing.assert_allclose(np.asarray(got["a"]), np.asarray(tree["a"]))
        assert got["b"]["c"].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got["b"]["c"], np.float32),
                                   1.5)
        assert int(got["b"]["d"]) == 7


def test_manager_keep_last_and_resume():
    tree = {"x": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=2)
        for s in (10, 20, 30):
            mgr.save_sync(s, {"x": jnp.full((4,), float(s))})
        steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                       if p.startswith("step_"))
        assert steps == [20, 30]
        step, got = mgr.restore_latest(tree)
        assert step == 30 and float(got["x"][0]) == 30.0


def test_restore_into_abstract_like():
    tree = {"w": jnp.ones((6, 2), jnp.float32)}
    like = {"w": jax.ShapeDtypeStruct((6, 2), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        got = restore(d, 1, like)
        np.testing.assert_allclose(np.asarray(got["w"]), 1.0)


# ----------------------------------------------------------------------
# fault tolerance / placement
# ----------------------------------------------------------------------

def test_straggler_flagging_and_recovery():
    mon = HeartbeatMonitor(4, patience=2, threshold=1.5)
    for _ in range(4):
        for h in range(3):
            mon.beat(h, 1.0)
        mon.beat(3, 4.0)
    assert mon.stragglers() == [3]
    # EWMA (α=0.2) needs ~12 healthy beats to decay 4.0 → <1.5× median
    for _ in range(14):
        for h in range(4):
            mon.beat(h, 1.0)
    assert mon.stragglers() == []


@settings(max_examples=10, deadline=None)
@given(n_fail=st.integers(1, 40), seed=st.integers(0, 3))
def test_remesh_plan_properties(n_fail, seed):
    topo = topology.multi_pod(2, 4, 4)       # 32 devices
    rng = np.random.RandomState(seed)
    failed = rng.choice(32, size=min(n_fail, 20), replace=False).tolist()
    plan = plan_elastic_remesh(topo, failed, (4, 8), model_axis_size=8)
    assert set(plan.surviving).isdisjoint(failed)
    assert len(plan.surviving) == plan.mesh_shape[0] * 8
    assert plan.mesh_shape[0] & (plan.mesh_shape[0] - 1) == 0  # pow2
    assert plan.data_parallel_scale <= 1.0


def test_supervisor_restores_after_failure():
    state = {"step_done": []}

    def run_step(s):
        state["step_done"].append(s)
        return [1.0]

    saved = {"at": 0}
    sup = Supervisor(
        num_hosts=1, checkpoint_every=5,
        run_step=run_step,
        save=lambda s: saved.__setitem__("at", s),
        restore=lambda: saved["at"],
        topo=topology.tpu_pod_2d(2, 2), mesh_shape=(2, 2),
        model_axis_size=2,
        remesh=lambda plan: None)
    final = sup.run(0, 20, inject_failure={12: [1]})
    assert final == 20
    kinds = [e for _, e in sup.events]
    assert any("failure" in k for k in kinds)
    assert any(k == "restored" for k in kinds)
    # the steps between the last checkpoint (10) and the failure (12)
    # were re-executed after restore
    assert state["step_done"].count(10) == 2 or state["step_done"].count(11) == 2


def test_priority_layout_valid_and_bounded():
    """The priority walk yields a valid permutation with bounded ring
    cost. (Finding recorded in EXPERIMENTS §Repro: on healthy toroidal
    meshes the hardware enumeration is already Hamiltonian-optimal, so
    the walk is NOT expected to beat it — it must just stay within a
    small factor and remain valid for degraded/irregular machines.)"""
    topo = topology.multi_pod(2, 4, 4)
    shape = (2, 4, 4)
    perm = placement.device_order_priority(topo, shape)
    assert sorted(perm.tolist()) == list(range(32))
    base = placement.layout_cost(topo, placement.device_order_baseline(topo),
                                 shape)
    pri = placement.layout_cost(topo, perm, shape)
    assert pri <= base * 2.0
    # rings of the walk never contain a cross-pod hop unless forced
    assert np.isfinite(pri) and pri > 0
