"""Tests for the declarative execution-context layer.

The tentpole contract: a :class:`BindingSpec`/:class:`PlacementSpec`
lowers bit-exactly to the imperative ``allocate_threads`` /
``first_touch_spill`` call chains it replaces, ``Machine.run`` equals
the positional ``simulate()`` shim, ``Machine.grid`` equals the
hand-written per-cell loop, and the registries validate like
``SCHEDULERS`` does.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import placement, priority, topology
from repro.core.sim import (BINDINGS, PLACEMENTS, BindingSpec, ExecContext,
                            Grid, Machine, PlacementSpec, SimParams,
                            SweepPlan, bots, context, get_binding,
                            get_placement, register_binding,
                            register_placement, serial_time, simulate)
from repro.core.sim import _csim

SUNFIRE = topology.sunfire_x4600()
TPU = topology.tpu_pod_2d(2, 4)
HAVE_C = _csim.load() is not None
ENGINES = ["py", "c"] if HAVE_C else ["py"]


@pytest.fixture(params=ENGINES)
def engine(request, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", request.param)
    return request.param


# ----------------------------------------------------------------------
# BindingSpec ≡ allocate_threads (both topologies)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("topo", [SUNFIRE, TPU], ids=["sunfire", "tpu2x4"])
def test_paper_binding_equals_allocate_threads(topo):
    spec = BINDINGS["paper"]
    for T in (1, 2, 5, topo.num_cores):
        for seed in (0, 3):
            assert spec.lower(topo, T, seed=seed) == \
                tuple(priority.allocate_threads(topo, T, seed=seed)), (T, seed)


def test_linear_scatter_node_fill_lowerings():
    assert BINDINGS["linear"].lower(SUNFIRE, 6) == tuple(range(6))
    # sunfire cores are node-contiguous: node_fill == linear there
    assert BINDINGS["node_fill"].lower(SUNFIRE, 7) == tuple(range(7))
    # scatter: one core per node per round, node ids ascending
    sc = BINDINGS["scatter"].lower(SUNFIRE, 10)
    assert sc[:8] == (0, 2, 4, 6, 8, 10, 12, 14)   # first core per node
    assert sc[8:] == (1, 3)                        # second round
    nodes = [int(SUNFIRE.core_node[c]) for c in sc[:8]]
    assert nodes == list(range(8))


def test_binding_lowering_cached_on_topology():
    spec = BINDINGS["paper"]
    assert spec.lower(SUNFIRE, 8) is spec.lower(SUNFIRE, 8)
    assert spec.lower(SUNFIRE, 8, seed=1) is not spec.lower(SUNFIRE, 8)
    # linear ignores the seed in its cache key
    assert BINDINGS["linear"].lower(SUNFIRE, 8, seed=1) is \
        BINDINGS["linear"].lower(SUNFIRE, 8, seed=2)


def test_explicit_binding_forms():
    assert get_binding("cores:3,1,5").lower(SUNFIRE) == (3, 1, 5)
    assert get_binding([4, 2]).lower(SUNFIRE) == (4, 2)
    assert get_binding(range(4)).lower(SUNFIRE, 4) == (0, 1, 2, 3)
    with pytest.raises(ValueError, match="pins 2 cores"):
        get_binding((0, 1)).lower(SUNFIRE, 3)
    with pytest.raises(ValueError, match="outside topology"):
        get_binding([0, 99]).lower(SUNFIRE)
    with pytest.raises(ValueError, match="duplicate"):
        get_binding([1, 1]).lower(SUNFIRE)


def test_binding_validation():
    with pytest.raises(ValueError, match="kind"):
        BindingSpec("x", kind="bogus")
    with pytest.raises(ValueError, match="non-empty"):
        BindingSpec("x", kind="explicit")
    with pytest.raises(ValueError, match="takes no"):
        BindingSpec("x", kind="linear", cores=(0, 1))
    with pytest.raises(ValueError, match="threads=99 out of range"):
        BINDINGS["linear"].lower(SUNFIRE, 99)
    with pytest.raises(ValueError, match="needs threads"):
        BINDINGS["paper"].lower(SUNFIRE)
    with pytest.raises(ValueError, match="unknown binding"):
        get_binding("bogus")
    with pytest.raises(ValueError, match="malformed"):
        get_binding("cores:1,x")
    with pytest.raises(TypeError):
        get_binding(1.5)


# ----------------------------------------------------------------------
# PlacementSpec ≡ first_touch_spill (both topologies)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("topo", [SUNFIRE, TPU], ids=["sunfire", "tpu2x4"])
def test_spill_placement_equals_first_touch_spill(topo):
    pr = priority.priorities(topo)
    for T in (2, topo.num_cores):
        master = priority.allocate_threads(topo, T)[0]
        mn = int(topo.core_node[master])
        for k in (1, 2, 3):
            # paper spill: from the master's node, priority tie-breaks
            spec = get_placement(f"spill:{k}")
            assert spec.lower(topo, master) == \
                tuple(placement.first_touch_spill(topo, mn, k, pr)), (T, k)
            # baseline spill: pinned start node, Linux node-id walk
            spec0 = get_placement(f"spill:{k}@0")
            assert spec0.lower(topo, master) == \
                tuple(placement.first_touch_spill(topo, 0, k)), (T, k)


def test_placement_lowerings():
    assert PLACEMENTS["first_touch"].lower(SUNFIRE, 0) is None
    assert PLACEMENTS["interleave"].lower(SUNFIRE, 5) == tuple(range(8))
    assert get_placement("node:3").lower(SUNFIRE, 0) == (3,)
    assert get_placement("nodes:1,3").lower(SUNFIRE, 0) == (1, 3)
    assert get_placement(4).lower(SUNFIRE, 0) == (4,)
    assert get_placement([2, 6]).lower(SUNFIRE, 0) == (2, 6)
    assert get_placement(None) is PLACEMENTS["first_touch"]
    # cached per (spec, resolved start node)
    spec = get_placement("spill:2")
    assert spec.lower(SUNFIRE, 6) is get_placement("spill:2").lower(SUNFIRE, 7)


def test_placement_validation():
    with pytest.raises(ValueError, match="kind"):
        PlacementSpec("x", kind="bogus")
    with pytest.raises(ValueError, match="ties"):
        PlacementSpec("x", kind="spill", ties="bogus")
    with pytest.raises(ValueError, match=">=1 node|≥1 node"):
        PlacementSpec("x", kind="spill", spill_nodes=0)
    with pytest.raises(ValueError, match="non-empty"):
        PlacementSpec("x", kind="explicit")
    with pytest.raises(ValueError, match="takes no"):
        PlacementSpec("x", kind="interleave", nodes=(1,))
    with pytest.raises(ValueError, match="spill over 99"):
        get_placement("spill:99").lower(SUNFIRE, 0)
    with pytest.raises(ValueError, match="start node 88"):
        get_placement("spill:1@88").lower(SUNFIRE, 0)
    with pytest.raises(ValueError, match="nodes \\[42\\] outside"):
        get_placement("node:42").lower(SUNFIRE, 0)
    with pytest.raises(ValueError, match="unknown placement"):
        get_placement("bogus")
    with pytest.raises(ValueError, match="malformed"):
        get_placement("spill:x")
    with pytest.raises(ValueError, match="malformed"):
        get_placement("spill:2@y")
    with pytest.raises(TypeError):
        get_placement(2.5)


# ----------------------------------------------------------------------
# registries
# ----------------------------------------------------------------------

def test_registry_roundtrip(monkeypatch):
    b = BindingSpec("tmp_binding", kind="scatter")
    monkeypatch.setitem(BINDINGS, "tmp_binding", b)
    assert get_binding("tmp_binding") is b
    p = PlacementSpec("tmp_place", kind="spill", spill_nodes=3)
    monkeypatch.setitem(PLACEMENTS, "tmp_place", p)
    assert get_placement("tmp_place") is p


def test_register_duplicate_guards():
    with pytest.raises(ValueError, match="already registered"):
        register_binding(BindingSpec("paper", kind="paper"))
    with pytest.raises(ValueError, match="already registered"):
        register_placement(PlacementSpec("first_touch"))
    # replace=True round-trips the stock entries unchanged
    assert register_binding(BINDINGS["paper"], replace=True) \
        is BINDINGS["paper"]
    assert register_placement(PLACEMENTS["interleave"], replace=True) \
        is PLACEMENTS["interleave"]


def test_stock_registry_contents():
    assert set(BINDINGS) >= {"paper", "linear", "scatter", "node_fill"}
    assert set(PLACEMENTS) >= {"first_touch", "interleave"}
    for spec in BINDINGS.values():
        assert spec.kind in context.BINDING_KINDS


# ----------------------------------------------------------------------
# ExecContext + Machine
# ----------------------------------------------------------------------

def test_exec_context_compile_fields():
    m = Machine(SUNFIRE)
    ctx = m.context(16, binding="paper", placement="spill:2",
                    runtime_data="master")
    assert ctx.threads == 16
    assert ctx.master_core == ctx.thread_cores[0]
    assert ctx.master_node == int(SUNFIRE.core_node[ctx.master_core])
    assert ctx.runtime_data_node == ctx.master_node
    assert ctx.label() == "paper/spill:2"
    assert len(ctx.root_data_nodes) == 2


def test_exec_context_validation():
    m = Machine(SUNFIRE)
    with pytest.raises(ValueError, match="runtime_data"):
        m.context(4, runtime_data="bogus")
    with pytest.raises(ValueError, match="runtime_data node 99"):
        m.context(4, runtime_data=99)
    with pytest.raises(ValueError, match="migration_rate"):
        m.context(4, migration_rate=1.5)
    with pytest.raises(ValueError, match="out of range"):
        m.context(99)


def test_machine_context_cached():
    m = Machine(SUNFIRE)
    c1 = m.context(8, binding="paper", placement="spill:2")
    c2 = m.context(8, binding="paper", placement="spill:2")
    assert c1 is c2
    assert m.context(8, binding="linear") is not c1
    # list forms normalize onto the same cache slot as their tuple twin
    assert m.context(binding=[0, 1, 2]) is m.context(binding=(0, 1, 2))


def test_machine_run_equals_simulate(engine):
    wl = bots.fft(n=1 << 10, cutoff=8)
    m = Machine(SUNFIRE)
    spill0 = placement.first_touch_spill(SUNFIRE, 0, 2)
    serial = serial_time(SUNFIRE, wl, 0, spill0)
    want = simulate(SUNFIRE, list(range(16)), wl, "wf", seed=0,
                    root_data_nodes=spill0, runtime_data_node=0,
                    migration_rate=0.15, serial_reference=serial)
    got = m.run(wl, "wf", seed=0, serial_reference=serial, threads=16,
                binding="linear", placement="spill:2@0", runtime_data=0,
                migration_rate=0.15)
    assert got == want
    alloc = priority.allocate_threads(SUNFIRE, 16)
    pr = priority.priorities(SUNFIRE)
    spill = placement.first_touch_spill(
        SUNFIRE, int(SUNFIRE.core_node[alloc[0]]), 2, pr)
    want = simulate(SUNFIRE, alloc, wl, "dfwsrpt", seed=4,
                    root_data_nodes=spill)
    got = m.run(wl, "dfwsrpt", seed=4, threads=16, binding="paper",
                placement="spill:2")
    assert got == want


def test_machine_run_rejects_context_plus_kwargs():
    m = Machine(SUNFIRE)
    ctx = m.context(4)
    with pytest.raises(ValueError, match="not both"):
        m.run(bots.fft(n=1 << 8, cutoff=8), "wf", context=ctx, threads=4)


def test_machine_serial_time_matches_legacy():
    wl = bots.fft(n=1 << 10, cutoff=8)
    m = Machine(SUNFIRE)
    spill0 = placement.first_touch_spill(SUNFIRE, 0, 2)
    assert m.serial_time(wl, placement="spill:2@0") == \
        serial_time(SUNFIRE, wl, 0, spill0)
    assert m.serial_time(wl) == serial_time(SUNFIRE, wl, 0, None)


def test_grid_equals_hand_loop(engine):
    """Acceptance: a mixed base/numa grid through Machine.grid equals
    the imperative allocate_threads/first_touch_spill loop, cell for
    cell."""
    wl = bots.fft(n=1 << 10, cutoff=8)
    m = Machine(SUNFIRE)
    pr = priority.priorities(SUNFIRE)
    spill0 = placement.first_touch_spill(SUNFIRE, 0, 2)
    serial = serial_time(SUNFIRE, wl, 0, spill0)
    g = m.grid(workloads=[wl], schedulers=("bf", "wf", "dfwsrpt"),
               threads=(2, 8), seeds=(0, 1),
               contexts={"base": dict(binding="linear", placement="spill:2@0",
                                      runtime_data=0, migration_rate=0.15),
                         "numa": dict(binding="paper", placement="spill:2")},
               serial_reference={"fft": serial})
    res = g.run()
    assert len(res) == 2 * 2 * 3 * 2
    for k, r in res.items():
        if k.context == "base":
            want = simulate(SUNFIRE, list(range(k.threads)), wl, k.scheduler,
                            seed=k.seed, root_data_nodes=spill0,
                            runtime_data_node=0, migration_rate=0.15,
                            serial_reference=serial)
        else:
            alloc = priority.allocate_threads(SUNFIRE, k.threads)
            spill = placement.first_touch_spill(
                SUNFIRE, int(SUNFIRE.core_node[alloc[0]]), 2, pr)
            want = simulate(SUNFIRE, alloc, wl, k.scheduler, seed=k.seed,
                            root_data_nodes=spill, serial_reference=serial)
        assert r == want, k


def test_grid_default_cross_and_concat(engine):
    wl1 = bots.fft(n=1 << 8, cutoff=8)
    wl2 = bots.sparselu(n=6)
    m = Machine(SUNFIRE)
    g1 = m.grid(workloads=wl1, schedulers="wf", threads=4,
                bindings=("paper", "linear"), placements=("first_touch",))
    assert [k.context for k in g1.keys] == ["paper/first_touch",
                                            "linear/first_touch"]
    g2 = m.grid(workloads=[wl2], schedulers=("wf",), threads=4)
    fused = Grid.concat([g1, g2])
    assert len(fused) == 3
    res = fused.run()
    assert list(res) == g1.keys + g2.keys
    for k, r in res.items():
        wl = wl1 if k.workload == "fft" else wl2
        cores = priority.allocate_threads(SUNFIRE, 4) \
            if k.context.startswith("paper") else list(range(4))
        assert r == simulate(SUNFIRE, cores, wl, "wf", seed=0), k


def test_grid_input_validation():
    m = Machine(SUNFIRE)
    wl = bots.fft(n=1 << 8, cutoff=8)
    with pytest.raises(ValueError, match="duplicate workload names"):
        m.grid(workloads=[wl, bots.fft(n=1 << 8, cutoff=8)],
               schedulers=("wf",), threads=2)
    with pytest.raises(ValueError, match="unknown scheduler"):
        m.grid(workloads=[wl], schedulers=("nope",), threads=2)


def test_grid_context_variant_threads_override(engine):
    """A contexts= variant may pin its own thread count; a pinned
    variant emits once even when the grid sweeps several counts."""
    wl = bots.fft(n=1 << 8, cutoff=8)
    m = Machine(SUNFIRE)
    g = m.grid(workloads=[wl], schedulers=("wf",), threads=(4, 8),
               contexts={"narrow": dict(binding="linear", threads=2),
                         "wide": dict(binding="linear")})
    res = g.run()
    assert [(k.context, k.threads) for k in res] == \
        [("narrow", 2), ("wide", 4), ("wide", 8)]
    for k, r in res.items():
        assert r == simulate(SUNFIRE, list(range(k.threads)), wl, "wf",
                             seed=0), k


def test_grid_contexts_exclusive_with_bindings_placements():
    m = Machine(SUNFIRE)
    wl = bots.fft(n=1 << 8, cutoff=8)
    with pytest.raises(ValueError, match="not both"):
        m.grid(workloads=[wl], schedulers=("wf",), threads=2,
               placements=("spill:2",),
               contexts={"v": dict(binding="paper")})
    with pytest.raises(ValueError, match="not both"):
        m.grid(workloads=[wl], schedulers=("wf",), threads=2,
               bindings=("linear",), contexts={"v": {}})


def test_grid_seeds_int_shorthand(engine):
    """seeds=n is Monte-Carlo shorthand for range(n): n replicas per
    cell, identical to passing the explicit tuple."""
    wl = bots.fft(n=1 << 8, cutoff=8)
    m = Machine(SUNFIRE)
    g = m.grid(workloads=[wl], schedulers=("wf",), threads=4, seeds=3)
    assert [k.seed for k in g.keys] == [0, 1, 2]
    explicit = m.grid(workloads=[wl], schedulers=("wf",), threads=4,
                      seeds=(0, 1, 2))
    assert g.keys == explicit.keys
    assert g.run() == explicit.run()


def test_grid_run_stats_exposes_raw_results(engine):
    wl = bots.fft(n=1 << 8, cutoff=8)
    m = Machine(SUNFIRE)
    g = m.grid(workloads=[wl], schedulers=("wf", "bf"), threads=4,
               seeds=4)
    raw = g.run()
    stats = g.run_stats()
    assert len(stats) == 2
    for k, cs in stats.items():
        assert k.seed is None
        assert cs.n == 4
        per_seed = [raw[k._replace(seed=s)] for s in range(4)]
        assert list(cs.results) == per_seed
        assert cs.makespan.min == min(r.makespan for r in per_seed)
        assert cs.makespan.max == max(r.makespan for r in per_seed)


def test_grid_rejects_duplicate_cells():
    """Colliding GridKeys would be silently collapsed by the result
    dict — run() must refuse instead."""
    m = Machine(SUNFIRE)
    wl = bots.fft(n=1 << 8, cutoff=8)
    g = m.grid(workloads=[wl], schedulers=("wf",), threads=2,
               seeds=(0, 0))
    with pytest.raises(ValueError, match="duplicate cells"):
        g.run()
    g1 = m.grid(workloads=[wl], schedulers=("wf",), threads=2)
    with pytest.raises(ValueError, match="duplicate cells"):
        Grid.concat([g1, g1]).run()


# ----------------------------------------------------------------------
# SweepPlan add()-time validation (names the offending cell)
# ----------------------------------------------------------------------

def test_sweep_add_validates_eagerly():
    wl = bots.fft(n=1 << 8, cutoff=8)
    plan = SweepPlan()
    plan.add(SUNFIRE, [0, 1], wl, "wf")     # fine
    with pytest.raises(ValueError, match=r"cell #1 \(fft/nope/T=2\).*"
                                         "unknown scheduler"):
        plan.add(SUNFIRE, [0, 1], wl, "nope")
    with pytest.raises(ValueError, match=r"cell #1.*cores \[99\]"):
        plan.add(SUNFIRE, [0, 99], wl, "wf")
    with pytest.raises(ValueError, match="duplicate cores"):
        plan.add(SUNFIRE, [1, 1], wl, "wf")
    with pytest.raises(ValueError, match="root data nodes \\[9\\]"):
        plan.add(SUNFIRE, [0, 1], wl, "wf", root_data_nodes=[0, 9])
    with pytest.raises(ValueError, match="runtime_data_node 12"):
        plan.add(SUNFIRE, [0, 1], wl, "wf", runtime_data_node=12)
    with pytest.raises(ValueError, match="migration_rate"):
        plan.add(SUNFIRE, [0, 1], wl, "wf", migration_rate=2.0)
    with pytest.raises(ValueError, match="not SimParams"):
        plan.add(SUNFIRE, [0, 1], wl, "wf", params={"hop_lambda": 1})
    with pytest.raises(ValueError, match="empty thread binding"):
        plan.add(SUNFIRE, [], wl, "wf")
    assert len(plan) == 1                   # failed adds appended nothing


def test_sweep_add_context_runs(engine):
    wl = bots.fft(n=1 << 8, cutoff=8)
    m = Machine(SUNFIRE)
    ctx = m.context(4, binding="paper", placement="spill:2")
    plan = SweepPlan()
    plan.add_context(ctx, wl, "dfwspt", seed=2)
    [r] = plan.run()
    assert r == m.run(wl, "dfwspt", seed=2, context=ctx)


def test_sim_params_frozen():
    p = SimParams()
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.hop_lambda = 9.9
    assert hash(p) == hash(SimParams())     # usable as a cache key


# ----------------------------------------------------------------------
# priority memoization satellite
# ----------------------------------------------------------------------

def test_priorities_memoized():
    topo = topology.sunfire_x4600()         # fresh topo: fresh caches
    p1 = priority.priorities(topo)
    p2 = priority.priorities(topo)
    assert p1 is p2
    assert not p1.total.flags.writeable     # shared arrays are read-only
    p3 = priority.priorities(topo, available=list(range(8)))
    assert p3 is not p1
    assert p3 is priority.priorities(topo, available=range(8))


def test_allocate_threads_memoized():
    topo = topology.sunfire_x4600()
    a1 = priority.allocate_threads(topo, 8, seed=1)
    a2 = priority.allocate_threads(topo, 8, seed=1)
    assert a1 == a2
    assert a1 is not a2                     # callers get a fresh list
    a2.append(-1)                           # ...so mutation is harmless
    assert priority.allocate_threads(topo, 8, seed=1) == a1
    assert priority.allocate_threads(topo, 8, seed=2) != a1
    # weights participate in the key
    w = priority.default_weights(topo.max_distance()) * 2
    aw = priority.allocate_threads(topo, 8, weights=w, seed=1)
    assert aw == priority.allocate_threads(topo, 8, weights=w, seed=1)


# ----------------------------------------------------------------------
# sparselu paper tier satellite
# ----------------------------------------------------------------------

def test_sparselu_flat_matches_compiled_tree():
    from repro.core.sim.table import compile_tree
    tf = bots.sparselu_flat(n=12).table
    tt = compile_tree(bots.sparselu(n=12).root)
    for field in ("work_pre", "work_post", "f_root", "f_parent",
                  "first_child", "num_children", "first_post", "num_post",
                  "parent", "cls"):
        assert np.array_equal(getattr(tf, field), getattr(tt, field)), field


def test_sparselu_flat_simulates_identically(engine):
    r1 = simulate(SUNFIRE, list(range(8)), bots.sparselu_flat(n=10),
                  "dfwsrpt", seed=7)
    r2 = simulate(SUNFIRE, list(range(8)), bots.sparselu(n=10),
                  "dfwsrpt", seed=7)
    assert r1 == r2


def test_sparselu_flat_validation():
    with pytest.raises(ValueError):
        bots.sparselu_flat(n=1)


@pytest.mark.slow
def test_sparselu_paper_scale():
    wl = bots.make("sparselu", "paper")
    assert wl.table.n >= bots.PAPER_MIN_TASKS
    m = Machine(SUNFIRE)
    r = m.run(wl, "dfwsrpt", seed=0, threads=16, binding="paper",
              placement="spill:2")
    assert r.makespan > 0 and r.steals > 0
