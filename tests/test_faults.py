"""Fault-injection layer tests: spec parsing, plan compilation,
engine parity under perturbation, hardened-sweep semantics, and
graceful engine degradation.

The invariants pinned here:

  * fault-free configurations stay bit-exact against the golden
    fixtures in BOTH engines — the fault hook adds zero behavior when
    no faults are configured (and even a compiled-but-neutral plan
    perturbs nothing);
  * a fault-enabled run is deterministic per (fault spec, seed) and
    bit-identical between the Python and C engines;
  * fault accounting (reclaimed / reexec / fault_lost) is consistent;
  * the step-count watchdog converts hung loops into diagnosable
    :class:`SimStalled` errors in both engines;
  * ``run_sweep(strict=False)`` isolates failing cells as
    :class:`CellError` slots, and ``Machine.grid`` aggregates every
    invalid cell into one error;
  * a forced C-build failure degrades to the Python engine with a
    one-time warning and golden-exact results.
"""

import json
import os
import warnings

import numpy as np
import pytest

from repro.core import topology
from repro.core.sim import (CellError, Machine, SimParams, SimResult,
                            SimStalled, SweepPlan, bots, run_context,
                            reset_engine_cache, simulate)
from repro.core.sim import _csim, runtime
from repro.core.sim.faults import (FAULT_STREAM, FaultPlan, FaultSpec,
                                   compile_fault_plan, get_fault,
                                   get_faults, register_fault, FAULTS)

GOLD = json.load(open(os.path.join(os.path.dirname(__file__), "data",
                                   "sim_golden.json")))
HAVE_C = _csim.load() is not None
ENGINES = ["py", "c"] if HAVE_C else ["py"]
TOPO = topology.sunfire_x4600()


@pytest.fixture(params=ENGINES)
def engine(request, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", request.param)
    return request.param


def _wl():
    return bots.fft(n=1 << 10, cutoff=8)


# ----------------------------------------------------------------------
# Spec parsing + registry
# ----------------------------------------------------------------------

def test_parse_straggler():
    s = get_fault("straggler:0.5")
    assert s.kind == "straggler" and s.severity == 0.5 and s.cores is None
    s = get_fault("straggler:1.25@2,5")
    assert s.cores == (2, 5)


def test_parse_preempt():
    s = get_fault("preempt:3")
    assert s.kind == "preempt" and s.count == 3.0 and s.duration == 20.0
    s = get_fault("preempt:2@7.5")
    assert s.duration == 7.5


def test_parse_fail():
    s = get_fault("fail:2")
    assert s.kind == "fail" and s.count == 2 and s.at is None
    s = get_fault("fail:1@30")
    assert s.at == 30.0


@pytest.mark.parametrize("bad", [
    "straggler", "bogus:1", "straggler:x", "straggler:-1",
    "preempt:1@-3", "fail:1.5", "fail:1@-2", "straggler:1@a,b",
])
def test_parse_rejects(bad):
    with pytest.raises(ValueError):
        get_fault(bad)


def test_get_faults_normalizes():
    assert get_faults(None) == ()
    assert get_faults(()) == ()
    (one,) = get_faults("fail:1")
    assert isinstance(one, FaultSpec)
    two = get_faults(["straggler:0.5", one])
    assert len(two) == 2 and two[1] is one
    with pytest.raises(TypeError):
        get_faults(42)


def test_registry_roundtrip():
    spec = FaultSpec("test-noisy-node", kind="preempt", count=2.0,
                     duration=5.0)
    try:
        register_fault(spec)
        assert get_fault("test-noisy-node") is spec
        with pytest.raises(ValueError, match="already registered"):
            register_fault(spec)
        register_fault(spec, replace=True)
    finally:
        FAULTS.pop("test-noisy-node", None)


def test_spec_validation():
    with pytest.raises(ValueError, match="outside topology"):
        get_fault("straggler:1@999").validate(TOPO, 8)
    with pytest.raises(ValueError, match="no survivor"):
        get_fault("fail:8").validate(TOPO, 8)
    with pytest.raises(ValueError, match="takes no explicit core"):
        FaultSpec("x", kind="fail", cores=(1,))


# ----------------------------------------------------------------------
# Plan compilation
# ----------------------------------------------------------------------

def test_compile_deterministic_and_cached():
    specs = get_faults(["preempt:2", "straggler:0.5"])
    cores = tuple(range(8))
    p1 = compile_fault_plan(specs, TOPO, cores, 3)
    p2 = compile_fault_plan(specs, TOPO, cores, 3)
    assert p1 is p2                       # cached on the topology
    p3 = compile_fault_plan(get_faults(["preempt:2", "straggler:0.5"]),
                            topology.sunfire_x4600(), cores, 3)
    np.testing.assert_array_equal(p1.speed, p3.speed)
    np.testing.assert_array_equal(p1.win_start, p3.win_start)
    np.testing.assert_array_equal(p1.win_end, p3.win_end)
    p4 = compile_fault_plan(specs, TOPO, cores, 4)  # new seed, new draws
    assert (p4.n_windows != p1.n_windows
            or not np.array_equal(p4.win_start, p1.win_start))


def test_compile_windows_merged_sorted():
    plan = compile_fault_plan(get_faults("preempt:4@30"), TOPO,
                              tuple(range(8)), 0)
    for th in range(8):
        lo, hi = plan.win_off[th], plan.win_off[th + 1]
        starts = plan.win_start[lo:hi]
        ends = plan.win_end[lo:hi]
        assert (starts[1:] > ends[:-1]).all()   # disjoint, sorted
        assert (ends > starts).all()


def test_compile_neutral_plan():
    plan = compile_fault_plan(get_faults("straggler:0@2"), TOPO,
                              tuple(range(8)), 0)
    assert plan.is_neutral and plan.n_windows == 0
    assert not compile_fault_plan(get_faults("fail:1"), TOPO,
                                  tuple(range(8)), 0).is_neutral


def test_compile_rejects_total_failure():
    spec = FaultSpec("all-dead", kind="fail", count=4, at=10.0)
    # two stacked fail specs can cover all threads even though each one
    # alone passes validate(); the aggregate check must still fire
    with pytest.raises(ValueError, match="no survivor"):
        compile_fault_plan((spec, spec), TOPO, tuple(range(4)), 0)


def test_fault_stream_disjoint_from_engine_stream():
    # the fault RNG is a dedicated stream: same seed, different draws
    a = np.random.RandomState([FAULT_STREAM, 7]).uniform(size=4)
    b = np.random.RandomState(7).uniform(size=4)
    assert not np.allclose(a, b)


# ----------------------------------------------------------------------
# Engine behavior under faults
# ----------------------------------------------------------------------

def _run(machine, wl, sched="dfwsrpt", faults=(), seed=0, T=8, **kw):
    ctx = machine.context(T, faults=faults, **kw)
    return run_context(ctx, wl, sched, seed=seed)


def test_fault_free_matches_golden(engine):
    """No faults configured → bit-exact against the golden fixtures."""
    wl = _wl()
    for sched in ("bf", "wf", "dfwsrpt"):
        r = simulate(TOPO, list(range(8)), wl, sched, seed=7)
        gold = GOLD[f"sunfire/fft_small/{sched}"]
        for m in ("makespan", "speedup", "steals", "failed_probes",
                  "remote_work_fraction", "queue_wait", "tasks"):
            assert getattr(r, m) == gold[m], (sched, m)
        assert r.reclaimed == 0 and r.reexec == 0 and r.fault_lost == 0.0


def test_neutral_plan_is_bit_exact(engine):
    """A compiled-but-neutral plan takes the fault code path yet changes
    nothing: the hook itself is free."""
    m = Machine(TOPO)
    wl = _wl()
    base = _run(m, wl, faults=())
    neutral = _run(m, wl, faults="straggler:0@2")
    assert base == neutral                # engine field excluded from eq


def test_fault_runs_deterministic(engine):
    m = Machine(TOPO)
    wl = _wl()
    for faults in ("straggler:0.5", "preempt:2@15", "fail:1@120"):
        runs = [_run(m, wl, faults=faults, seed=11) for _ in range(2)]
        assert runs[0] == runs[1], faults


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
@pytest.mark.parametrize("sched", ["bf", "cilk", "wf", "dfwspt",
                                   "dfwsrpt", "dfwshier"])
@pytest.mark.parametrize("faults", ["straggler:0.75", "preempt:2@15",
                                    "fail:2@80",
                                    ("straggler:0.5@1", "preempt:1")])
def test_engine_parity_under_faults(sched, faults, monkeypatch):
    """py and C produce bit-identical results under every fault kind,
    across all schedulers (shared bf queue included)."""
    m = Machine(TOPO)
    wl = _wl()
    out = {}
    for eng in ("py", "c"):
        monkeypatch.setenv("REPRO_SIM_ENGINE", eng)
        out[eng] = _run(m, wl, sched=sched, faults=faults, seed=5)
    assert out["py"] == out["c"]
    assert out["py"].engine == "py" and out["c"].engine == "c"


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_engine_parity_faults_with_migration(monkeypatch):
    """Migration draws + straggler speed lookups stay in lockstep (a
    migrated thread can land on — or leave — a slow core)."""
    m = Machine(TOPO)
    wl = _wl()
    out = {}
    for eng in ("py", "c"):
        monkeypatch.setenv("REPRO_SIM_ENGINE", eng)
        out[eng] = _run(m, wl, sched="wf", faults="straggler:1.0",
                        seed=3, migration_rate=0.15)
    assert out["py"] == out["c"]


def test_fault_accounting(engine):
    m = Machine(TOPO)
    wl = _wl()
    base = _run(m, wl, faults=())
    master = m.context(8).thread_cores[0]   # the core running the root
    slow = _run(m, wl, faults=f"straggler:2.0@{master}")
    # a 3x straggler on the master core must inflate makespan
    assert slow.makespan > base.makespan
    assert slow.reclaimed == 0 and slow.fault_lost == 0.0
    pre = _run(m, wl, faults="preempt:3@25")
    assert pre.reclaimed >= 0 and pre.reexec >= 0
    assert pre.fault_lost >= 0.0
    fail = _run(m, wl, faults="fail:2@60")
    assert fail.tasks == base.tasks       # every task still executed
    assert fail.reclaimed >= 1            # the dead threads' work moved
    assert fail.makespan > 60.0


def test_permanent_failure_completes(engine):
    """Workload completes even when most threads die early: survivors
    reclaim and re-execute everything."""
    m = Machine(TOPO)
    wl = _wl()
    r = _run(m, wl, faults="fail:6@10", T=8)
    assert isinstance(r, SimResult)
    assert r.tasks == _run(m, wl).tasks


def test_watchdog_stalls(engine):
    """An exhausted step budget raises SimStalled naming the scheduler,
    step count, and last event time — in both engines."""
    m = Machine(TOPO, params=SimParams(max_steps=10))
    with pytest.raises(SimStalled) as ei:
        _run(m, _wl(), sched="wf")
    e = ei.value
    assert e.reason == "watchdog" and e.scheduler == "wf"
    assert e.steps > 10 and e.last_t >= 0.0
    assert "wf" in str(e) and "watchdog" in str(e)


def test_watchdog_auto_budget_passes(engine):
    """The default (auto) budget is far above any legitimate run."""
    m = Machine(TOPO)
    r = _run(m, _wl(), faults="preempt:2")
    assert r.makespan > 0.0


# ----------------------------------------------------------------------
# Hardened sweep harness
# ----------------------------------------------------------------------

def test_sweep_strict_false_isolates_cells(engine):
    wl = _wl()
    ok = Machine(TOPO)
    stall = Machine(TOPO, params=SimParams(max_steps=8))
    plan = SweepPlan()
    plan.add_context(ok.context(8), wl, "wf")
    plan.add_context(stall.context(8), wl, "wf", label="doomed-cell")
    plan.add_context(ok.context(8), wl, "dfwsrpt")
    res = plan.run(strict=False)
    assert isinstance(res[0], SimResult)
    assert isinstance(res[1], CellError) and res[1].index == 1
    assert res[1].label == "doomed-cell"
    assert isinstance(res[1].error, SimStalled)
    assert isinstance(res[2], SimResult)   # batch continued past failure


def test_sweep_strict_true_names_cell(engine):
    wl = _wl()
    stall = Machine(TOPO, params=SimParams(max_steps=8))
    plan = SweepPlan()
    plan.add_context(stall.context(8), wl, "wf", label="doomed-cell")
    with pytest.raises(SimStalled, match="doomed-cell"):
        plan.run()


def test_sweep_add_collects_errors():
    wl = _wl()
    plan = SweepPlan()
    errors: list = []
    assert plan.add(TOPO, [0, 1, 999], wl, "wf", errors=errors) is None
    assert plan.add(TOPO, [0, 1], wl, "nosuch", errors=errors) is None
    assert plan.add(TOPO, [0, 1], wl, "wf", errors=errors) is not None
    assert len(errors) == 2 and len(plan) == 1
    assert any("999" in e for e in errors)
    assert any("unknown scheduler" in e for e in errors)


def test_grid_fault_axis():
    m = Machine(TOPO)
    wl = _wl()
    master = m.context(8).thread_cores[0]
    slow = f"straggler:1.0@{master}"
    g = m.grid(workloads=[wl], schedulers=("wf", "dfwsrpt"), threads=8,
               faults=[None, slow])
    res = g.run()
    assert len(res) == 4
    by_fault = {k.faults: r for k, r in res.items() if k.scheduler == "wf"}
    assert set(by_fault) == {"none", slow}
    assert by_fault[slow].makespan > by_fault["none"].makespan


def test_grid_aggregated_validation():
    """Every invalid cell in a grid expansion is reported in ONE error —
    bad schedulers, malformed fault entries, impossible fault plans."""
    m = Machine(TOPO)
    wl = _wl()
    with pytest.raises(ValueError) as ei:
        m.grid(workloads=[wl], schedulers=("wf", "nosuch1", "nosuch2"),
               threads=8, faults=[None, "straggler:-3"])
    msg = str(ei.value)
    assert "invalid grid cell" in msg
    assert "unknown scheduler" in msg
    assert "nosuch1" in msg and "nosuch2" in msg
    assert "straggler:-3" in msg


def test_grid_run_strict_false():
    m = Machine(TOPO)
    stall = Machine(TOPO, params=SimParams(max_steps=8))
    wl = _wl()
    g = stall.grid(workloads=[wl], schedulers=("wf",), threads=8)
    out = g.run(strict=False)
    (v,) = out.values()
    assert isinstance(v, CellError)
    # strict default still raises
    with pytest.raises(SimStalled):
        stall.grid(workloads=[wl], schedulers=("wf",), threads=8).run()


# ----------------------------------------------------------------------
# Graceful engine degradation
# ----------------------------------------------------------------------

def test_c_build_failure_falls_back(monkeypatch):
    """A broken toolchain degrades to the Python engine: one warning,
    cached choice, golden-exact results."""
    def broken_build():
        raise RuntimeError("forced: no C compiler in this test")

    monkeypatch.setenv("REPRO_SIM_ENGINE", "auto")
    monkeypatch.setattr(_csim, "_build", broken_build)
    reset_engine_cache()
    try:
        wl = _wl()
        with pytest.warns(RuntimeWarning, match="falling back"):
            r = simulate(TOPO, list(range(8)), wl, "wf", seed=7)
        assert r.engine == "py"
        gold = GOLD["sunfire/fft_small/wf"]
        assert r.makespan == gold["makespan"]
        assert r.steals == gold["steals"]
        # the choice is cached: no second warning
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            r2 = simulate(TOPO, list(range(8)), wl, "wf", seed=7)
        assert not [w for w in caught
                    if issubclass(w.category, RuntimeWarning)]
        assert r2 == r
        # forcing engine=c under the broken toolchain is a hard error
        monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
        reset_engine_cache()
        with pytest.raises(RuntimeError, match="unavailable"):
            simulate(TOPO, list(range(8)), wl, "wf", seed=7)
    finally:
        reset_engine_cache()              # forget the poisoned attempt
