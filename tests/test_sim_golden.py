"""Golden-parity tests for the flat simulation engine.

``tests/data/sim_golden.json`` holds metrics recorded from the original
(seed) pure-Python object-based engine for all 5 stock schedulers × 2
small workloads × 2 topologies (+ one unbound-baseline variant
exercising migration and centralized runtime data), plus fixtures for
the policy-layer scheduler ``dfwshier`` recorded from the flat Python
engine. The flat engine — in both its pure-Python and compiled-C forms
— must reproduce every metric exactly: the rewrite preserves behavior
draw-for-draw, not just statistically.
"""

import json
import os

import numpy as np
import pytest

from repro.core import placement, topology
from repro.core.sim import SweepPlan, bots, simulate
from repro.core.sim import _csim
from repro.core.sim.table import compile_tree

GOLD = json.load(open(os.path.join(os.path.dirname(__file__), "data",
                                   "sim_golden.json")))
TOPOS = {"sunfire": topology.sunfire_x4600(),
         "tpu2x4": topology.tpu_pod_2d(2, 4)}
SCHEDS = ("bf", "cilk", "wf", "dfwspt", "dfwsrpt", "dfwshier")
METRICS = ("makespan", "speedup", "steals", "failed_probes",
           "remote_work_fraction", "queue_wait", "tasks")

HAVE_C = _csim.load() is not None
ENGINES = ["py", "c"] if HAVE_C else ["py"]


def _small_workloads():
    return {"fft_small": bots.fft(n=1 << 10, cutoff=8),
            "sparselu_small": bots.sparselu(n=8)}


@pytest.fixture(params=ENGINES)
def engine(request, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", request.param)
    return request.param


def _assert_matches(r, key):
    gold = GOLD[key]
    for m in METRICS:
        assert getattr(r, m) == gold[m], (key, m, getattr(r, m), gold[m])


@pytest.mark.parametrize("topo_name", sorted(TOPOS))
@pytest.mark.parametrize("sched", SCHEDS)
def test_golden_parity(engine, topo_name, sched):
    """Flat engines reproduce the seed engine bit-for-bit on fixtures."""
    topo = TOPOS[topo_name]
    for wl_name, wl in _small_workloads().items():
        r = simulate(topo, list(range(8)), wl, sched, seed=7)
        _assert_matches(r, f"{topo_name}/{wl_name}/{sched}")


@pytest.mark.parametrize("topo_name", sorted(TOPOS))
def test_golden_parity_batched(engine, topo_name):
    """The same fixtures through the batched sweep path — the one that
    dispatches across the worker pool. ``REPRO_SIM_WORKERS`` (the CI
    matrix runs 1 and 4) must never change a bit."""
    topo = TOPOS[topo_name]
    plan, keys = SweepPlan(), []
    for wl_name, wl in _small_workloads().items():
        for sched in SCHEDS:
            plan.add(topo, list(range(8)), wl, sched, seed=7)
            keys.append(f"{topo_name}/{wl_name}/{sched}")
    for r, key in zip(plan.run(), keys):
        _assert_matches(r, key)


def test_golden_parity_baseline_numa(engine):
    """The unbound-baseline variant: migration draws + centralized
    runtime data + spilled root arrays, all bit-exact."""
    topo = TOPOS["sunfire"]
    wl = _small_workloads()["fft_small"]
    r = simulate(topo, list(range(16)), wl, "wf", seed=3,
                 root_data_nodes=placement.first_touch_spill(topo, 0, 2),
                 runtime_data_node=0, migration_rate=0.15)
    _assert_matches(r, "sunfire/fft_small/wf+baseline-numa")


def test_determinism(engine):
    """Same seed → bit-identical SimResult across repeated runs."""
    topo = TOPOS["sunfire"]
    wl = bots.fft(n=1 << 10, cutoff=8)
    runs = [simulate(topo, list(range(8)), wl, "dfwsrpt", seed=11,
                     migration_rate=0.1, runtime_data_node=0)
            for _ in range(3)]
    assert runs[0] == runs[1] == runs[2]


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
@pytest.mark.parametrize("sched", SCHEDS)
def test_cross_engine_exact(sched, monkeypatch):
    """C and Python engines agree exactly on configs beyond the fixtures
    (different seeds/threads, uma topology, migration, runtime node)."""
    cases = [
        (TOPOS["sunfire"], list(range(0, 16, 2)), dict(seed=5)),
        (TOPOS["tpu2x4"], list(range(4)), dict(seed=1, migration_rate=0.3,
                                               runtime_data_node=2)),
        (topology.uma(6), list(range(6)), dict(seed=9)),
        # single-core machine + migration: numpy's randint(1) consumes
        # no rng draws — a replica divergence caught by verification.
        (topology.uma(1), [0], dict(seed=0, migration_rate=0.5)),
    ]
    for topo, cores, kw in cases:
        wl = bots.floorplan(depth=4)
        monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
        r_py = simulate(topo, cores, wl, sched, **kw)
        monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
        r_c = simulate(topo, cores, wl, sched, **kw)
        assert r_py == r_c, (sched, topo.name, kw)


# ----------------------------------------------------------------------
# flat builders
# ----------------------------------------------------------------------

TABLE_FIELDS = ("work_pre", "work_post", "f_root", "f_parent",
                "first_child", "num_children", "first_post", "num_post",
                "parent", "cls", "cls_f_root", "cls_f_parent")


@pytest.mark.parametrize("flat,tree", [
    (lambda: bots.fft_flat(n=1 << 10, cutoff=8),
     lambda: bots.fft(n=1 << 10, cutoff=8)),
    (lambda: bots.sort_flat(n=1 << 10, cutoff=16),
     lambda: bots.sort(n=1 << 10, cutoff=16)),
    (lambda: bots.strassen_flat(depth=3),
     lambda: bots.strassen(depth=3)),
    (lambda: bots.sparselu_flat(n=10),
     lambda: bots.sparselu(n=10)),
])
def test_flat_builder_matches_compiled_tree(flat, tree):
    """The iterative CSR builders are exact twins of tree compilation."""
    tf = flat().table
    tt = compile_tree(tree().root)
    for field in TABLE_FIELDS:
        assert np.array_equal(getattr(tf, field), getattr(tt, field)), field


def test_flat_builder_simulates_identically():
    """A flat-built workload and its tree twin give identical results."""
    topo = TOPOS["sunfire"]
    wf = bots.fft_flat(n=1 << 10, cutoff=8)
    wt = bots.fft(n=1 << 10, cutoff=8)
    r1 = simulate(topo, list(range(8)), wf, "dfwsrpt", seed=7)
    r2 = simulate(topo, list(range(8)), wt, "dfwsrpt", seed=7)
    assert r1 == r2


@pytest.mark.slow
def test_paper_scale_builds_fast_enough():
    """Paper tier: ≥1M tasks, builds + simulates well under a minute."""
    import time
    from repro.core import priority
    t0 = time.time()
    wl = bots.make("fft", "paper")
    assert wl.table.n >= bots.PAPER_MIN_TASKS
    topo = TOPOS["sunfire"]
    alloc = priority.allocate_threads(topo, 16)
    r = simulate(topo, alloc, wl, "dfwsrpt", seed=0)
    assert time.time() - t0 < 60.0
    assert r.makespan > 0 and r.steals > 0
    for name in ("sort", "strassen", "sparselu"):
        assert bots.make(name, "paper").table.n >= bots.PAPER_MIN_TASKS


# ----------------------------------------------------------------------
# C kernel replica selftests
# ----------------------------------------------------------------------

@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_c_mt19937_matches_numpy():
    lib = _csim.load()
    for seed in (0, 7, 12345):
        out = np.zeros(3000, dtype=np.uint32)
        lib.mt_selftest(seed, 3000, out)
        want = np.random.RandomState(seed).randint(
            0, 2 ** 32, size=3000, dtype=np.uint32)
        assert np.array_equal(out, want)


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_c_shuffle_matches_numpy():
    lib = _csim.load()
    for n in (2, 5, 15):
        reps = 300
        rows = np.zeros((reps, n), dtype=np.int64)
        lib.shuffle_selftest(3, n, reps, rows.ravel())
        rng = np.random.RandomState(3)
        for r in range(reps):
            g = list(range(n))
            rng.shuffle(g)
            assert list(rows[r]) == g


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_c_set_replica_matches_cpython():
    """The wake-one park set in C replicates CPython's set add/pop."""
    import random
    lib = _csim.load()
    rnd = random.Random(123)
    for _ in range(150):
        T = rnd.choice([2, 3, 8, 16, 64, 300])
        ops, ref, s = [], [], set()
        for _ in range(rnd.randrange(5, 300)):
            if s and rnd.random() < 0.45:
                ops.append(-1)
                ref.append(s.pop())
            else:
                v = rnd.randrange(T)
                ops.append(v)
                s.add(v)
        arr = np.array(ops, dtype=np.int64)
        out = np.zeros(max(len(ops), 1), dtype=np.int64)
        npop = lib.set_selftest(len(ops), arr, out)
        assert npop == len(ref) and list(out[:npop]) == ref


# ----------------------------------------------------------------------
# topology satellites
# ----------------------------------------------------------------------

def test_core_distance_matrix_cached():
    topo = topology.sunfire_x4600()
    m1 = topo.core_distance_matrix()
    m2 = topo.core_distance_matrix()
    assert m1 is m2  # cached, not rebuilt per simulate() call
    assert not m1.flags.writeable
    expect = topo.node_distance[topo.core_node][:, topo.core_node]
    assert np.array_equal(m1, expect)


def test_hop_histogram_vectorized_semantics():
    for topo in (topology.sunfire_x4600(), topology.tpu_pod_2d(3, 3),
                 topology.uma(4)):
        d = topo.core_distance_matrix()
        for core in range(topo.num_cores):
            hist = {}
            for other in range(topo.num_cores):
                if other != core:
                    k = int(d[core, other])
                    hist[k] = hist.get(k, 0) + 1
            assert topo.hop_histogram(core) == hist
