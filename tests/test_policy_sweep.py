"""Tests for the scheduler-policy layer and the batched sweep runner.

The tentpole contract: scheduler identity is a declarative
:class:`SchedulerSpec` compiled once into victim-plan arrays consumed
identically by the C and Python engines; ``SCHEDULERS`` is a registry;
a :class:`SweepPlan` batch is bit-identical to the per-call
``simulate()`` loop on the same grid.
"""

import os

import numpy as np
import pytest

from repro.core import placement, priority, topology
from repro.core.sim import (SCHEDULERS, SchedulerSpec, SimParams, SweepPlan,
                            bots, policy, reset_engine_cache, simulate)
from repro.core.sim import _csim
from repro.core.sim.sweep import run_sweep

TOPO = topology.sunfire_x4600()
HAVE_C = _csim.load() is not None
ENGINES = ["py", "c"] if HAVE_C else ["py"]


@pytest.fixture(params=ENGINES)
def engine(request, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", request.param)
    return request.param


# ----------------------------------------------------------------------
# SchedulerSpec + registry
# ----------------------------------------------------------------------

def test_stock_registry_contents():
    assert set(SCHEDULERS) >= {"bf", "cilk", "wf", "dfwspt", "dfwsrpt",
                               "dfwshier"}
    assert SCHEDULERS["bf"].queue == "shared"
    assert SCHEDULERS["wf"].spawn == "child_first"
    assert SCHEDULERS["cilk"].spawn == "parent_first"
    assert SCHEDULERS["dfwspt"].victim == "dist_id"
    assert SCHEDULERS["dfwsrpt"].victim == "dist_random"
    assert SCHEDULERS["dfwshier"].victim == "node_hier"


def test_spec_validation():
    with pytest.raises(ValueError):
        SchedulerSpec("x", queue="bogus")
    with pytest.raises(ValueError):
        SchedulerSpec("x", spawn="bogus")
    with pytest.raises(ValueError):
        SchedulerSpec("x", victim="bogus")
    with pytest.raises(ValueError):  # shared queue has no victim sweep
        SchedulerSpec("x", queue="shared", spawn="parent_first",
                      victim="random")
    with pytest.raises(ValueError):  # child_first needs local pools
        SchedulerSpec("x", queue="shared", spawn="child_first",
                      victim="none")


def test_unknown_scheduler_rejected():
    wl = bots.fft(n=1 << 8, cutoff=8)
    with pytest.raises(ValueError, match="unknown scheduler"):
        simulate(TOPO, [0, 1], wl, "nope")


def test_register_duplicate_guard():
    with pytest.raises(ValueError, match="already registered"):
        policy.register(SchedulerSpec("wf"))


def test_register_new_policy_runs_without_engine_edits(engine, monkeypatch):
    """A brand-new field combination — parent-first spawning with
    hierarchical stealing — runs through both engines unchanged."""
    name = f"cilk_hier_{engine}"
    # setitem instead of policy.register() so the global registry is
    # restored after the test (register() is itself covered above)
    monkeypatch.setitem(policy.SCHEDULERS, name,
                        SchedulerSpec(name, queue="local",
                                      spawn="parent_first",
                                      victim="node_hier"))
    wl = bots.fft(n=1 << 10, cutoff=8)
    r1 = simulate(TOPO, list(range(8)), wl, name, seed=7)
    r2 = simulate(TOPO, list(range(8)), wl, name, seed=7)
    assert r1 == r2 and r1.steals > 0
    # a spec object is accepted directly, no registration needed
    r3 = simulate(TOPO, list(range(8)), wl, SCHEDULERS[name], seed=7)
    assert r3 == r1


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_new_policy_cross_engine_exact(monkeypatch):
    spec = SchedulerSpec("anon_hier", queue="local", spawn="parent_first",
                         victim="node_hier")
    wl = bots.sparselu(n=8)
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    r_py = simulate(TOPO, list(range(10)), wl, spec, seed=5)
    monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
    r_c = simulate(TOPO, list(range(10)), wl, spec, seed=5)
    assert r_py == r_c


def test_victim_plan_cached_per_binding():
    spec = SCHEDULERS["dfwsrpt"]
    p1 = policy.compile_victim_plan(spec, TOPO, range(8))
    p2 = policy.compile_victim_plan(spec, TOPO, list(range(8)))
    assert p1 is p2
    p3 = policy.compile_victim_plan(spec, TOPO, range(6))
    assert p3 is not p1


def test_victim_plan_matches_stealing_module():
    """The compiled dist_id plan is the stealing module's static list."""
    from repro.core.stealing import priority_list
    cores = list(range(12))
    plan = policy.compile_victim_plan(SCHEDULERS["dfwspt"], TOPO, cores)
    for th in range(12):
        assert plan.static_order[th] == priority_list(TOPO, cores, th)


def test_victim_plan_flat_arrays_consistent():
    cores = list(range(8))
    for name in ("cilk", "dfwspt", "dfwsrpt", "dfwshier"):
        plan = policy.compile_victim_plan(SCHEDULERS[name], TOPO, cores)
        goff, uoff, voff, victims = plan.flat()
        assert goff.shape == (9,)
        assert uoff.shape == (goff[-1] + 1,)
        assert voff.shape == (uoff[-1] + 1,)
        assert victims.shape == (voff[-1],)
        for th in range(8):
            emitted = []
            for g in range(goff[th], goff[th + 1]):
                for u in range(uoff[g], uoff[g + 1]):
                    emitted.extend(victims[voff[u]:voff[u + 1]].tolist())
            assert sorted(emitted) == [v for v in range(8) if v != th]


# ----------------------------------------------------------------------
# engine selection satellites
# ----------------------------------------------------------------------

def test_simresult_reports_engine(engine):
    wl = bots.fft(n=1 << 8, cutoff=8)
    r = simulate(TOPO, [0, 1], wl, "wf")
    assert r.engine == engine


def test_engine_choice_tracks_env_and_reset(monkeypatch):
    wl = bots.fft(n=1 << 8, cutoff=8)
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    assert simulate(TOPO, [0, 1], wl, "wf").engine == "py"
    if HAVE_C:  # cache is keyed on the env value: no reset needed
        monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
        assert simulate(TOPO, [0, 1], wl, "wf").engine == "c"
    monkeypatch.setenv("REPRO_SIM_ENGINE", "bogus")
    with pytest.raises(ValueError, match="REPRO_SIM_ENGINE"):
        simulate(TOPO, [0, 1], wl, "wf")
    reset_engine_cache()  # the test-visible hook
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    assert simulate(TOPO, [0, 1], wl, "wf").engine == "py"


def test_engine_field_excluded_from_equality():
    r1 = simulate(TOPO, [0, 1], bots.fft(n=1 << 8, cutoff=8), "wf")
    import dataclasses
    r2 = dataclasses.replace(r1, engine="other")
    assert r1 == r2


# ----------------------------------------------------------------------
# batched sweeps
# ----------------------------------------------------------------------

def test_sweep_matches_per_call_loop(engine):
    """A mixed grid (schedulers × threads × workloads × placements) is
    bit-identical between SweepPlan.run() and the simulate() loop."""
    wls = [bots.fft(n=1 << 10, cutoff=8), bots.sparselu(n=8)]
    spill = placement.first_touch_spill(TOPO, 0, 2)
    plan = SweepPlan()
    singles = []
    for wl in wls:
        for sched in SCHEDULERS:
            for T in (4, 8):
                kw = dict(seed=11, root_data_nodes=spill,
                          runtime_data_node=0, migration_rate=0.1)
                plan.add(TOPO, list(range(T)), wl, sched, **kw)
                singles.append(simulate(TOPO, list(range(T)), wl, sched,
                                        **kw))
    assert plan.run() == singles


def test_sweep_empty_and_config_order(engine):
    assert SweepPlan().run() == []
    wl = bots.fft(n=1 << 8, cutoff=8)
    plan = SweepPlan()
    plan.add(TOPO, [0, 1], wl, "wf", seed=1)
    plan.add(TOPO, [0, 1], wl, "bf", seed=1)
    r = plan.run()
    assert len(r) == len(plan) == 2
    assert r[0].steals >= 0 and r[1].queue_wait >= 0
    assert r[0] == simulate(TOPO, [0, 1], wl, "wf", seed=1)


def test_run_sweep_accepts_config_sequence(engine):
    from repro.core.sim.sweep import SweepConfig
    wl = bots.fft(n=1 << 8, cutoff=8)
    cfgs = [SweepConfig(TOPO, (0, 1, 2), wl, "dfwsrpt", seed=3)]
    assert run_sweep(cfgs) == [simulate(TOPO, [0, 1, 2], wl, "dfwsrpt",
                                        seed=3)]


def test_sweep_serial_reference_defaults(engine):
    """Without an explicit reference the sweep derives the same serial
    time (master core + placement) as simulate() does."""
    wl = bots.strassen(depth=3)
    plan = SweepPlan()
    plan.add(TOPO, list(range(6)), wl, "dfwspt", seed=0,
             root_data_nodes=1)
    assert plan.run() == [simulate(TOPO, list(range(6)), wl, "dfwspt",
                                   seed=0, root_data_nodes=1)]


@pytest.mark.slow
def test_figs_grid_sweep_parity():
    """Acceptance: the full Figs 5–10 grid through ``Machine.grid()``
    equals the hand-written per-call simulate() loop (the pre-facade
    driver, verbatim), speedup for speedup."""
    import benchmarks.bots_repro as br
    from repro.core.sim import serial_time
    pr = priority.priorities(br.TOPO)
    for name in ("fft", "nqueens"):
        swept = br.run_benchmark(name)
        wl = br._workload(name)
        spill0 = placement.first_touch_spill(br.TOPO, 0, br.SPILL[name])
        serial = serial_time(br.TOPO, wl, 0, spill0, br.PARAMS)
        for T in br.THREADS:
            alloc = priority.allocate_threads(br.TOPO, T)
            mn = int(br.TOPO.core_node[alloc[0]])
            spill_n = placement.first_touch_spill(br.TOPO, mn,
                                                  br.SPILL[name], pr)
            for sched in ("bf", "cilk", "wf"):
                r = simulate(br.TOPO, list(range(T)), wl, sched,
                             params=br.PARAMS, seed=0,
                             root_data_nodes=spill0, runtime_data_node=0,
                             migration_rate=br.MIGRATION,
                             serial_reference=serial)
                assert swept[(sched, "base", T)] == r.speedup, (name, sched, T)
                r = simulate(br.TOPO, alloc, wl, sched, params=br.PARAMS,
                             seed=0, root_data_nodes=spill_n,
                             serial_reference=serial)
                assert swept[(sched, "numa", T)] == r.speedup, (name, sched, T)


# ----------------------------------------------------------------------
# nqueens paper tier
# ----------------------------------------------------------------------

def test_nqueens_flat_small_structure():
    wl = bots.nqueens_flat(n=8, cutoff_depth=3, seed=1)
    tbl = wl.table
    assert tbl.parent[0] == -1
    # internal nodes carry the join continuation, leaves don't
    internal = tbl.num_children > 0
    assert np.all(tbl.work_post[internal] == 0.5)
    assert np.all(tbl.work_post[~internal] == 0.0)
    # irregular fan-out: not all internal nodes spawn the same count
    depth1 = tbl.num_children[tbl.parent == 0]
    assert tbl.num_children.max() > 1
    # per-level branch bound: children count never exceeds n - depth
    assert tbl.num_children[0] <= 8
    assert depth1.max() <= 7
    # deterministic per seed, different across seeds
    w2 = bots.nqueens_flat(n=8, cutoff_depth=3, seed=1)
    assert np.array_equal(tbl.work_pre, w2.table.work_pre)
    w3 = bots.nqueens_flat(n=8, cutoff_depth=3, seed=2)
    assert not np.array_equal(tbl.work_pre, w3.table.work_pre)


def test_nqueens_flat_simulates(engine):
    wl = bots.nqueens_flat(n=7, cutoff_depth=3, seed=0)
    r = simulate(TOPO, list(range(8)), wl, "dfwsrpt", seed=4)
    assert r.makespan > 0 and r.tasks == wl.table.n


def test_nqueens_flat_validation():
    with pytest.raises(ValueError):
        bots.nqueens_flat(n=4, cutoff_depth=0)
    with pytest.raises(ValueError):
        bots.nqueens_flat(n=3, cutoff_depth=5)


@pytest.mark.slow
def test_nqueens_paper_scale():
    wl = bots.make("nqueens", "paper")
    assert wl.table.n >= bots.PAPER_MIN_TASKS
    alloc = priority.allocate_threads(TOPO, 16)
    r = simulate(TOPO, alloc, wl, "dfwsrpt", seed=0)
    assert r.makespan > 0 and r.steals > 0
