"""Compatibility shim for ``hypothesis`` in offline environments.

The test suite uses a small subset of hypothesis (``given``/``settings``
plus the ``integers``/``sampled_from`` strategies). The real package is
not installable in the hermetic CI container, so when it is absent we
degrade to a deterministic property harness: each ``@given`` test is run
against a fixed number of pseudo-randomly drawn examples (seeded, so
failures are reproducible), honouring ``settings(max_examples=...)``.

Import through this module instead of ``hypothesis`` directly::

    from _hypothesis_compat import given, settings, strategies as st
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A draw rule: callable on a ``random.Random`` instance."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Records max_examples on the wrapped function; other hypothesis
        settings (deadline, ...) have no meaning in the shim."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            def runner(*args, **kwargs):
                n = getattr(runner, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                # Deterministic per-test stream: same examples every run.
                rng = random.Random(f"compat:{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    drawn = {name: strat.example(rng)
                             for name, strat in strategy_kwargs.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example {drawn!r}: {e}") from e

            # NB: no functools.wraps — pytest would follow __wrapped__
            # and treat the drawn parameters as fixtures.
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco
