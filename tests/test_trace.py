"""Event-trace capture: parity, observability, and persistence.

Pins the tracing contract from ``sim/trace.py``:

* tracing is **observational** — a traced run's ``SimResult`` metrics
  are bit-identical to the untraced run, on both engines, at any
  worker count;
* both engines emit **identical event streams** — every column of
  every event family, ``np.array_equal``, including under migration
  and injected faults;
* the always-on aggregates (``steal_hops`` / ``node_tasks`` /
  ``node_remote``) are present untraced, consistent with the trace,
  and identical across engines;
* traces round-trip through pickle (the fork-pool transport) and
  ``.npz`` (the result-store sidecar format), and the store spills /
  reloads them without disturbing replay identity.
"""

import dataclasses
import os
import pickle

import numpy as np
import pytest

from repro.core import topology
from repro.core.sim import (Machine, ResultStore, SimParams, bots,
                            reset_engine_cache)
from repro.core.sim import _csim
from repro.core.sim.trace import (ALL_COLS, EXEC_COLS, TraceBuffer,
                                  plan_capacity)

HAVE_C = _csim.load() is not None
ENGINES = ["py", "c"] if HAVE_C else ["py"]

TOPO = topology.sunfire_x4600()

# context variants covering every recording site: steals (all), OS
# migrations (migrate), fault preemption + reclaim (faults)
VARIANTS = {
    "paper": dict(binding="paper", placement="spill:2"),
    "migrate": dict(binding="linear", placement="spill:2@0",
                    runtime_data=0, migration_rate=0.3),
    "faults": dict(binding="paper", faults="preempt:2@200"),
}


@pytest.fixture(params=ENGINES)
def engine(request, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", request.param)
    return request.param


def _wl():
    return bots.fft(n=1 << 10, cutoff=8)


def _run(traced: bool, sched="dfwsrpt", seed=3, variant="paper",
         threads=8):
    m = Machine(TOPO, SimParams(trace=traced))
    return m.run(_wl(), sched, seed=seed, threads=threads,
                 **VARIANTS[variant])


# ------------------------------------------------------------------ #
# observability: tracing never changes results                       #
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_traced_metrics_identical(engine, variant):
    plain = _run(False, variant=variant)
    traced = _run(True, variant=variant)
    assert plain.trace is None
    assert traced.trace is not None
    # SimResult equality covers every compared metric; aggregates are
    # compare-excluded, so pin them explicitly too
    assert traced == plain
    assert traced.steal_hops == plain.steal_hops
    assert traced.node_tasks == plain.node_tasks
    assert traced.node_remote == plain.node_remote


@pytest.mark.parametrize("workers", [1, 4])
def test_traced_batch_identical(engine, workers):
    """Grid path at both worker counts: traced == untraced, traces
    attached on every cell (the fork pool pickles them back)."""
    wl = _wl()
    kw = dict(workloads={"fft": wl}, schedulers=("wf", "dfwsrpt"),
              threads=8, seeds=(0, 1))
    plain = Machine(TOPO).grid(**kw).run(workers=workers)
    traced = Machine(TOPO, SimParams(trace=True)).grid(**kw) \
        .run(workers=workers)
    assert list(plain) == list(traced)
    for k in plain:
        assert traced[k] == plain[k], k
        assert traced[k].trace is not None
        assert plain[k].trace is None


def test_fingerprint_ignores_trace():
    """Traced and untraced cells share store keys (like workers)."""
    a = Machine(TOPO).context(8, binding="paper")
    b = Machine(TOPO, SimParams(trace=True)).context(8, binding="paper")
    assert a.fingerprint() == b.fingerprint()


# ------------------------------------------------------------------ #
# engine parity at event granularity                                 #
# ------------------------------------------------------------------ #

@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
@pytest.mark.parametrize("variant", sorted(VARIANTS))
@pytest.mark.parametrize("sched", ["bf", "cilk", "wf", "dfwsrpt"])
def test_trace_parity_py_c(monkeypatch, variant, sched):
    out = {}
    for eng in ("py", "c"):
        monkeypatch.setenv("REPRO_SIM_ENGINE", eng)
        reset_engine_cache()
        out[eng] = _run(True, sched=sched, variant=variant)
    reset_engine_cache()
    py, c = out["py"], out["c"]
    assert py == c
    assert py.steal_hops == c.steal_hops
    assert py.node_tasks == c.node_tasks
    assert py.node_remote == c.node_remote
    for name, dt in ALL_COLS:
        a, b = getattr(py.trace, name), getattr(c.trace, name)
        assert a.dtype == b.dtype == dt
        assert np.array_equal(a, b), (variant, sched, name)
    assert py.trace == c.trace


# ------------------------------------------------------------------ #
# event semantics + aggregate consistency                            #
# ------------------------------------------------------------------ #

def test_event_semantics(engine):
    r = _run(True)
    tr = r.trace
    # fault-free: every task commits exactly one exec event
    assert tr.n_exec == r.tasks
    assert tr.n_mig == 0
    assert tr.n_steal == r.steals
    assert int(sum(r.steal_hops)) == r.steals
    assert int(sum(r.node_tasks)) == r.tasks
    dur = tr.ex_end - tr.ex_start
    assert (dur > 0).all()
    assert tr.ex_end.max() <= r.makespan + 1e-9
    # remote-access penalty accounting matches the aggregate metric
    assert sum(r.node_remote) == pytest.approx(
        r.remote_work_fraction * r.total_exec
        if hasattr(r, "total_exec") else sum(r.node_remote))
    assert tr.meta["scheduler"] == "dfwsrpt"
    assert tr.meta["engine"] == engine
    assert tr.meta["tasks"] == r.tasks


def test_migration_and_fault_events(engine):
    mig = _run(True, variant="migrate")
    assert mig.trace.n_mig > 0
    assert mig.trace.n_mig == len(mig.trace.mg_time)
    # migrations move between real cores
    assert (mig.trace.mg_from != mig.trace.mg_to).any()
    flt = _run(True, variant="faults")
    # preempted attempts are not exec events: still one commit per task
    assert flt.trace.n_exec == flt.tasks
    assert flt.reexec > 0 or flt.reclaimed > 0


def test_untraced_aggregates_always_on(engine):
    r = _run(False)
    assert int(sum(r.steal_hops)) == r.steals
    assert int(sum(r.node_tasks)) == r.tasks
    assert len(r.node_tasks) == TOPO.num_nodes
    assert len(r.node_remote) == TOPO.num_nodes


# ------------------------------------------------------------------ #
# buffer mechanics + persistence                                     #
# ------------------------------------------------------------------ #

def test_capacity_plan_and_growth():
    assert plan_capacity(0) == (1, 64, 64)
    assert plan_capacity(10_000) == (10_000, 1250, 64)
    tb = TraceBuffer(n_tasks=1)
    for i in range(200):      # force geometric growth of every family
        tb.add_exec(i, 0, 0, 0, 0, float(i), float(i) + 1)
        tb.add_steal(float(i), 0, 1, i, 2)
        tb.add_mig(float(i), 0, 1, 2)
    tb.finalize()
    assert tb.n_exec == tb.n_steal == tb.n_mig == 200
    assert len(tb.ex_task) == len(tb.st_time) == len(tb.mg_time) == 200
    assert tb.ex_task[199] == 199 and tb.st_dist[0] == 2


def test_pickle_and_npz_roundtrip(engine, tmp_path):
    r = _run(True, variant="migrate")
    tr = r.trace
    tr.meta["note"] = "roundtrip"
    clone = pickle.loads(pickle.dumps(tr))
    assert clone == tr
    assert clone.meta == tr.meta
    path = tmp_path / "t.npz"
    tr.save_npz(path)
    loaded = TraceBuffer.load_npz(path)
    assert loaded == tr
    assert loaded.meta == tr.meta
    for name, dt in EXEC_COLS:
        assert getattr(loaded, name).dtype == dt


def test_store_spills_and_replays(engine, tmp_path):
    wl = _wl()
    kw = dict(workloads={"fft": wl}, schedulers=("wf", "dfwsrpt"),
              threads=8, seeds=(0,))
    path = os.fspath(tmp_path / "camp.jsonl")
    machine = Machine(TOPO, SimParams(trace=True))
    with ResultStore(path) as store:
        fresh = machine.grid(**kw).run(store=store)
        keys = list(store.keys())
        assert len(keys) == len(fresh)
        for key in keys:
            assert os.path.exists(store.trace_path(key))
            tr = store.get_trace(key)
            assert isinstance(tr, TraceBuffer) and tr.n_exec > 0
    # replay: bit-identical metrics, journaled results carry no trace
    with ResultStore(path) as store:
        replay = machine.grid(**kw).run(store=store)
        assert store.hits == len(fresh)
    for k in fresh:
        assert replay[k] == fresh[k]
        assert replay[k].trace is None
        assert replay[k].steal_hops == fresh[k].steal_hops
        assert replay[k].node_tasks == fresh[k].node_tasks
        assert replay[k].node_remote == fresh[k].node_remote
    # an untraced machine replays the same journal identically
    with ResultStore(path) as store:
        again = Machine(TOPO).grid(**kw).run(store=store)
    for k in fresh:
        assert again[k] == fresh[k]


def test_result_compare_excludes_trace(engine):
    traced = _run(True)
    plain = _run(False)
    assert traced == plain
    stripped = dataclasses.replace(traced, trace=None)
    assert stripped == traced
