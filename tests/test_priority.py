"""Unit + property tests for the paper's priority allocation (Figs 2-4)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import priority, topology


def test_x4600_shape():
    topo = topology.sunfire_x4600()
    assert topo.num_cores == 16
    assert topo.num_nodes == 8
    assert topo.max_distance() == 3           # paper: up to 3 hops
    d = topo.node_distance
    assert (d == d.T).all() and (np.diag(d) == 0).all()


def test_priorities_levels_positive():
    topo = topology.sunfire_x4600()
    pr = priority.priorities(topo)
    assert (pr.v1 > 0).all() and (pr.v2 > 0).all()
    assert np.isfinite(pr.total).all()


def test_uma_all_equal():
    """Paper: equal node sizes + uniform distances ⇒ same priority."""
    topo = topology.uma(8)
    pr = priority.priorities(topo)
    assert np.allclose(pr.total, pr.total[0])


def test_central_nodes_outrank_corners():
    """X4600 I/O corners (nodes 0, 6) must rank below inner sockets."""
    topo = topology.sunfire_x4600()
    pr = priority.priorities(topo)
    corner = max(pr.total[0], pr.total[1], pr.total[12], pr.total[13])
    inner = min(pr.total[4], pr.total[6], pr.total[8], pr.total[10])
    assert inner > corner


def test_master_is_top_priority():
    topo = topology.sunfire_x4600()
    pr = priority.priorities(topo)
    alloc = priority.allocate_threads(topo, 16, seed=1)
    assert pr.total[alloc[0]] == pr.total.max()
    assert len(set(alloc)) == 16              # all distinct cores


def test_workers_cluster_near_master():
    """Paper: workers placed as close as possible to the master."""
    topo = topology.sunfire_x4600()
    alloc = priority.allocate_threads(topo, 4, seed=0)
    dist = topo.core_distance_matrix()
    d_in = max(dist[alloc[0], c] for c in alloc[1:])
    others = [c for c in range(16) if c not in alloc]
    # every allocated worker is at least as close as the nearest skipped core
    assert d_in <= min(dist[alloc[0], c] for c in others) + 1


def test_occupied_cores_excluded():
    topo = topology.sunfire_x4600()
    avail = list(range(8))
    alloc = priority.allocate_threads(topo, 4, available=avail)
    assert set(alloc) <= set(avail)


def test_weights_must_decrease():
    topo = topology.sunfire_x4600()
    with pytest.raises(ValueError):
        priority.priorities(topo, weights=np.array([1.0, 1.0, 0.5, 0.2]))


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(2, 4), cols=st.integers(2, 4),
       seed=st.integers(0, 5))
def test_allocation_valid_on_tori(rows, cols, seed):
    """Property: any torus — allocation is a valid, deterministic set."""
    topo = topology.tpu_pod_2d(rows, cols)
    n = topo.num_cores
    a1 = priority.allocate_threads(topo, n, seed=seed)
    a2 = priority.allocate_threads(topo, n, seed=seed)
    assert a1 == a2                           # deterministic per seed
    assert sorted(a1) == list(range(n))       # a permutation


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 15), seed=st.integers(0, 3))
def test_prefix_consistency(k, seed):
    """Allocating k threads yields a prefix-stable master (thread 0)."""
    topo = topology.sunfire_x4600()
    a_full = priority.allocate_threads(topo, 16, seed=seed)
    a_k = priority.allocate_threads(topo, k, seed=seed)
    assert a_k[0] == a_full[0]
