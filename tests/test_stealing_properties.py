"""Property tests for the NUMA-aware victim orders (hypothesis shim).

These pin the *contract* of ``stealing.victim_order`` /
``steal_order_matrix`` that both the paper's schedulers and the policy
layer's compiled victim plans rely on: every sweep is a permutation of
the other threads, sorted by non-decreasing hop distance, with
policy-specific tie handling inside each equal-distance group.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import topology
from repro.core.stealing import (priority_list, steal_order_matrix,
                                 victim_order)

TOPOS = [topology.sunfire_x4600(), topology.tpu_pod_2d(2, 4),
         topology.uma(8)]
POLICIES = ("dfwspt", "dfwsrpt", "dfwshier")


def _setup(topo_i, T, thread_raw, seed):
    topo = TOPOS[topo_i]
    T = min(T, topo.num_cores)
    cores = list(range(T))
    return topo, cores, thread_raw % T, np.random.RandomState(seed)


@settings(max_examples=40, deadline=None)
@given(topo_i=st.integers(0, len(TOPOS) - 1), T=st.integers(2, 16),
       thread_raw=st.integers(0, 15), seed=st.integers(0, 5),
       policy=st.sampled_from(POLICIES))
def test_victim_order_is_permutation_of_others(topo_i, T, thread_raw,
                                               seed, policy):
    topo, cores, thread, rng = _setup(topo_i, T, thread_raw, seed)
    order = victim_order(topo, cores, thread, policy, rng)
    assert sorted(order) == [t for t in range(len(cores)) if t != thread]


@settings(max_examples=40, deadline=None)
@given(topo_i=st.integers(0, len(TOPOS) - 1), T=st.integers(2, 16),
       thread_raw=st.integers(0, 15), seed=st.integers(0, 5),
       policy=st.sampled_from(POLICIES))
def test_victim_order_distance_non_decreasing(topo_i, T, thread_raw,
                                              seed, policy):
    topo, cores, thread, rng = _setup(topo_i, T, thread_raw, seed)
    dist = topo.core_distance_matrix()
    order = victim_order(topo, cores, thread, policy, rng)
    ds = [dist[cores[thread], cores[v]] for v in order]
    assert ds == sorted(ds)


@settings(max_examples=25, deadline=None)
@given(topo_i=st.integers(0, len(TOPOS) - 1), T=st.integers(2, 16),
       thread_raw=st.integers(0, 15))
def test_dfwspt_ties_ascend_by_id(topo_i, T, thread_raw):
    """Within each equal-distance group DFWSPT victims ascend by id, and
    the order is static (rng-independent, equal to priority_list)."""
    topo, cores, thread, rng = _setup(topo_i, T, thread_raw, 0)
    dist = topo.core_distance_matrix()
    order = victim_order(topo, cores, thread, "dfwspt", rng)
    for a, b in zip(order, order[1:]):
        da = dist[cores[thread], cores[a]]
        db = dist[cores[thread], cores[b]]
        if da == db:
            assert a < b
    assert order == priority_list(topo, cores, thread)
    assert order == victim_order(topo, cores, thread, "dfwspt",
                                 np.random.RandomState(123))


@settings(max_examples=25, deadline=None)
@given(topo_i=st.integers(0, len(TOPOS) - 1), T=st.integers(2, 16),
       thread_raw=st.integers(0, 15), seed=st.integers(0, 5))
def test_dfwsrpt_permutes_only_within_distance_groups(topo_i, T,
                                                      thread_raw, seed):
    """DFWSRPT's randomization never crosses a distance boundary: the
    *set* of victims in each equal-distance segment matches DFWSPT's."""
    topo, cores, thread, rng = _setup(topo_i, T, thread_raw, seed)
    dist = topo.core_distance_matrix()
    rand = victim_order(topo, cores, thread, "dfwsrpt", rng)
    static = priority_list(topo, cores, thread)

    def groups(order):
        by_d = {}
        for v in order:
            by_d.setdefault(int(dist[cores[thread], cores[v]]),
                            set()).add(v)
        return by_d

    assert groups(rand) == groups(static)


@settings(max_examples=25, deadline=None)
@given(topo_i=st.integers(0, len(TOPOS) - 1), T=st.integers(2, 16),
       thread_raw=st.integers(0, 15), seed=st.integers(0, 5))
def test_dfwshier_node_members_contiguous(topo_i, T, thread_raw, seed):
    """DFWSHIER probes one node's victims contiguously (id asc) before
    moving on — no node appears in two separate runs."""
    topo, cores, thread, rng = _setup(topo_i, T, thread_raw, seed)
    order = victim_order(topo, cores, thread, "dfwshier", rng)
    runs = []  # (node, [victims...]) runs in sweep order
    for v in order:
        node = int(topo.core_node[cores[v]])
        if runs and runs[-1][0] == node:
            runs[-1][1].append(v)
        else:
            runs.append((node, [v]))
    assert len({node for node, _ in runs}) == len(runs)
    for _, vs in runs:
        assert vs == sorted(vs)


@settings(max_examples=20, deadline=None)
@given(topo_i=st.integers(0, len(TOPOS) - 1), T=st.integers(2, 16),
       thread_raw=st.integers(0, 15), seed=st.integers(0, 5))
def test_dfwshier_matches_compiled_plan_sweep(topo_i, T, thread_raw, seed):
    """victim_order('dfwshier') from a fresh RandomState(seed) equals
    the engine's first sweep of the compiled VictimPlan for that seed —
    the ahead-of-time form and the simulator agree."""
    from repro.core.sim import SCHEDULERS
    from repro.core.sim.policy import compile_victim_plan
    topo, cores, thread, _ = _setup(topo_i, T, thread_raw, seed)
    plan = compile_victim_plan(SCHEDULERS["dfwshier"], topo, cores)
    rng = np.random.RandomState(seed)
    swept = []
    for tag, payload in plan.py_groups[thread]:
        if tag == 0:
            swept.extend(payload)
        elif tag == 1:
            g = list(payload)
            rng.shuffle(g)
            swept.extend(g)
        else:
            units = list(payload)
            rng.shuffle(units)
            for u in units:
                swept.extend(u)
    got = victim_order(topo, cores, thread, "dfwshier",
                       np.random.RandomState(seed))
    assert got == swept


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("topo", TOPOS, ids=lambda t: t.name)
def test_steal_order_matrix_rows(topo, policy):
    """Each row is that thread's victim permutation, distance-sorted,
    and the whole matrix is reproducible from its seed."""
    T = min(8, topo.num_cores)
    cores = list(range(T))
    dist = topo.core_distance_matrix()
    m = steal_order_matrix(topo, cores, policy, seed=3)
    assert m.shape == (T, T - 1)
    for th in range(T):
        row = [int(v) for v in m[th]]
        assert sorted(row) == [t for t in range(T) if t != th]
        ds = [dist[cores[th], cores[v]] for v in row]
        assert ds == sorted(ds)
    assert np.array_equal(m, steal_order_matrix(topo, cores, policy, seed=3))
