"""Simulator tests: determinism, invariants, and the paper's headline
qualitative claims (bf collapse on data-intensive benchmarks, NUMA-aware
allocation gains, scheduler ordering).

The paper's two execution variants are declarative contexts on the
:class:`Machine` facade: ``BASE`` is baseline Nanos (threads in OS
enumeration order and unbound, runtime + root data on node 0), ``NUMA``
is the paper's model (priority binding, local runtime data, spill from
the master's node). The determinism/invariant tests stay on the legacy
positional ``simulate()`` shim so both entry points keep coverage.
"""

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import topology
from repro.core.sim import Machine, bots, simulate, SCHEDULERS, TaskSpec

TOPO = topology.sunfire_x4600()
M = Machine(TOPO)

# the paper's two execution variants (spill size 2 — the data-intensive
# benchmarks' regime)
BASE = dict(threads=16, binding="linear", placement="spill:2@0",
            runtime_data=0, migration_rate=0.15)
NUMA = dict(threads=16, binding="paper", placement="spill:2")


def test_deterministic():
    wl = bots.fft(n=1 << 10, cutoff=8)
    r1 = simulate(TOPO, list(range(8)), wl, "wf", seed=3)
    r2 = simulate(TOPO, list(range(8)), wl, "wf", seed=3)
    assert r1.makespan == r2.makespan and r1.steals == r2.steals


def test_all_work_executes():
    """Makespan ≥ total work / threads (work conservation bound)."""
    wl = bots.sort(n=1 << 10, cutoff=8)
    for sched in SCHEDULERS:
        r = simulate(TOPO, list(range(8)), wl, sched, seed=0)
        assert r.makespan >= wl.root.total_work() / 8
        assert r.speedup <= 8.5               # no spurious super-linear


def test_single_thread_close_to_serial():
    wl = bots.fft(n=1 << 10, cutoff=8)
    r = simulate(TOPO, [0], wl, "wf", seed=0)
    assert 0.9 <= r.speedup <= 1.0 + 1e-9


def test_bf_collapses_on_fft():
    """Paper Fig 7: breadth-first degrades for FFT beyond ~6 cores."""
    wl = bots.fft(n=1 << 15, cutoff=4)
    serial = M.serial_time(wl, placement="spill:2@0")
    sp = {}
    for T in (6, 16):
        r = M.run(wl, "bf", seed=0, serial_reference=serial,
                  **{**BASE, "threads": T})
        sp[T] = r.speedup
    ws = M.run(wl, "wf", seed=0, serial_reference=serial, **BASE)
    assert sp[16] < sp[6] * 1.35              # no scaling 6 → 16
    assert ws.speedup > 2.5 * sp[16]          # work stealing far ahead


def test_numa_allocation_helps_data_intensive():
    """Paper §V: NUMA-aware allocation speeds up FFT/Sort/Strassen."""
    for name in ("fft", "strassen"):
        wl = bots.make(name, "medium") if name != "fft" \
            else bots.fft(n=1 << 14, cutoff=4)
        serial = M.serial_time(wl, placement="spill:2@0")
        base = M.run(wl, "wf", seed=0, serial_reference=serial, **BASE)
        numa = M.run(wl, "wf", seed=0, serial_reference=serial, **NUMA)
        assert numa.speedup > base.speedup * 1.02, name


def test_numa_gain_small_for_compute_bound():
    """Paper: NQueens gains only ~1.35% (compute-bound)."""
    wl = bots.nqueens(n=11)
    serial = M.serial_time(wl, placement="spill:1@0")
    base = M.run(wl, "wf", seed=0, serial_reference=serial,
                 **{**BASE, "placement": "spill:1@0"})
    numa = M.run(wl, "wf", seed=0, serial_reference=serial,
                 **{**NUMA, "placement": "spill:1"})
    gain = numa.speedup / base.speedup - 1
    assert -0.05 < gain < 0.15


def test_dfwspt_stealing_is_local():
    """NUMA-aware stealing keeps probes closer than random stealing."""
    wl = bots.strassen(depth=4)
    r_wf = M.run(wl, "wf", seed=0, **NUMA)
    r_pt = M.run(wl, "dfwspt", seed=0, **NUMA)
    assert r_pt.steals > 0 and r_wf.steals > 0
    assert r_pt.makespan <= r_wf.makespan * 1.1


@settings(max_examples=15, deadline=None)
@given(sched=st.sampled_from(sorted(SCHEDULERS)),
       T=st.sampled_from([2, 4, 8]), seed=st.integers(0, 3))
def test_speedup_bounds_property(sched, T, seed):
    """Property: 0 < speedup ≤ T (+small slack) for any scheduler/thread mix."""
    wl = bots.floorplan(depth=4)
    r = simulate(TOPO, list(range(T)), wl, sched, seed=seed)
    assert 0 < r.speedup <= T * 1.1


@settings(max_examples=10, deadline=None)
@given(depth=st.integers(1, 4), branch=st.integers(1, 5))
def test_taskspec_counts(depth, branch):
    """Property: count/total_work agree with an independent recursion."""
    def build(d):
        kids = [build(d - 1) for _ in range(branch)] if d else []
        return TaskSpec(work_pre=1.0, work_post=0.5, children=kids)
    root = build(depth)
    expect = sum(branch ** i for i in range(depth + 1))
    assert root.count() == expect
    assert root.total_work() == pytest.approx(1.5 * expect)


def test_paper_fft_scheduler_ordering():
    """Integration: the paper's FFT@16 ordering
    bf ≪ cilk ≤ wf < {wf,cilk}+NUMA ≤ DFWSPT/DFWSRPT — the whole
    comparison as one declarative grid."""
    wl = bots.fft(n=1 << 15, cutoff=4)
    serial = M.serial_time(wl, placement="spill:2@0")
    g = M.grid(workloads=[wl],
               schedulers=("bf", "wf", "dfwspt", "dfwsrpt"),
               contexts={"base": BASE, "numa": NUMA},
               serial_reference=serial)
    sp = {(k.context, k.scheduler): r.speedup for k, r in g.run().items()}
    assert sp[("base", "bf")] < 0.5 * sp[("base", "wf")]
    assert sp[("numa", "wf")] > sp[("base", "wf")]
    assert max(sp[("numa", "dfwspt")], sp[("numa", "dfwsrpt")]) >= \
        sp[("numa", "wf")] * 0.98
