"""Simulator tests: determinism, invariants, and the paper's headline
qualitative claims (bf collapse on data-intensive benchmarks, NUMA-aware
allocation gains, scheduler ordering)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import placement, priority, topology
from repro.core.sim import (SimParams, bots, serial_time, simulate,
                            SCHEDULERS, TaskSpec, Workload)

TOPO = topology.sunfire_x4600()
PR = priority.priorities(TOPO)


def _numa_setup(T):
    alloc = priority.allocate_threads(TOPO, T)
    mn = int(TOPO.core_node[alloc[0]])
    spill = placement.first_touch_spill(TOPO, mn, 2, PR)
    return alloc, spill


def test_deterministic():
    wl = bots.fft(n=1 << 10, cutoff=8)
    r1 = simulate(TOPO, list(range(8)), wl, "wf", seed=3)
    r2 = simulate(TOPO, list(range(8)), wl, "wf", seed=3)
    assert r1.makespan == r2.makespan and r1.steals == r2.steals


def test_all_work_executes():
    """Makespan ≥ total work / threads (work conservation bound)."""
    wl = bots.sort(n=1 << 10, cutoff=8)
    for sched in SCHEDULERS:
        r = simulate(TOPO, list(range(8)), wl, sched, seed=0)
        assert r.makespan >= wl.root.total_work() / 8
        assert r.speedup <= 8.5               # no spurious super-linear


def test_single_thread_close_to_serial():
    wl = bots.fft(n=1 << 10, cutoff=8)
    r = simulate(TOPO, [0], wl, "wf", seed=0)
    assert 0.9 <= r.speedup <= 1.0 + 1e-9


def test_bf_collapses_on_fft():
    """Paper Fig 7: breadth-first degrades for FFT beyond ~6 cores."""
    wl = bots.fft(n=1 << 15, cutoff=4)
    spill = placement.first_touch_spill(TOPO, 0, 2)
    serial = serial_time(TOPO, wl, 0, spill)
    sp = {}
    for T in (6, 16):
        r = simulate(TOPO, list(range(T)), wl, "bf", seed=0,
                     root_data_nodes=spill, runtime_data_node=0,
                     migration_rate=0.15, serial_reference=serial)
        sp[T] = r.speedup
    ws = simulate(TOPO, list(range(16)), wl, "wf", seed=0,
                  root_data_nodes=spill, runtime_data_node=0,
                  migration_rate=0.15, serial_reference=serial)
    assert sp[16] < sp[6] * 1.35              # no scaling 6 → 16
    assert ws.speedup > 2.5 * sp[16]          # work stealing far ahead


def test_numa_allocation_helps_data_intensive():
    """Paper §V: NUMA-aware allocation speeds up FFT/Sort/Strassen."""
    for name in ("fft", "strassen"):
        wl = bots.make(name, "medium") if name != "fft" \
            else bots.fft(n=1 << 14, cutoff=4)
        spill0 = placement.first_touch_spill(TOPO, 0, 2)
        serial = serial_time(TOPO, wl, 0, spill0)
        base = simulate(TOPO, list(range(16)), wl, "wf", seed=0,
                        root_data_nodes=spill0, runtime_data_node=0,
                        migration_rate=0.15, serial_reference=serial)
        alloc, spill = _numa_setup(16)
        numa = simulate(TOPO, alloc, wl, "wf", seed=0,
                        root_data_nodes=spill, serial_reference=serial)
        assert numa.speedup > base.speedup * 1.02, name


def test_numa_gain_small_for_compute_bound():
    """Paper: NQueens gains only ~1.35% (compute-bound)."""
    wl = bots.nqueens(n=11)
    spill0 = placement.first_touch_spill(TOPO, 0, 1)
    serial = serial_time(TOPO, wl, 0, spill0)
    base = simulate(TOPO, list(range(16)), wl, "wf", seed=0,
                    root_data_nodes=spill0, runtime_data_node=0,
                    migration_rate=0.15, serial_reference=serial)
    alloc, spill = _numa_setup(16)
    numa = simulate(TOPO, alloc, wl, "wf", seed=0,
                    root_data_nodes=spill[:1], serial_reference=serial)
    gain = numa.speedup / base.speedup - 1
    assert -0.05 < gain < 0.15


def test_dfwspt_stealing_is_local():
    """NUMA-aware stealing keeps probes closer than random stealing."""
    wl = bots.strassen(depth=4)
    alloc, spill = _numa_setup(16)
    r_wf = simulate(TOPO, alloc, wl, "wf", seed=0, root_data_nodes=spill)
    r_pt = simulate(TOPO, alloc, wl, "dfwspt", seed=0,
                    root_data_nodes=spill)
    assert r_pt.steals > 0 and r_wf.steals > 0
    assert r_pt.makespan <= r_wf.makespan * 1.1


@settings(max_examples=15, deadline=None)
@given(sched=st.sampled_from(sorted(SCHEDULERS)),
       T=st.sampled_from([2, 4, 8]), seed=st.integers(0, 3))
def test_speedup_bounds_property(sched, T, seed):
    """Property: 0 < speedup ≤ T (+small slack) for any scheduler/thread mix."""
    wl = bots.floorplan(depth=4)
    r = simulate(TOPO, list(range(T)), wl, sched, seed=seed)
    assert 0 < r.speedup <= T * 1.1


@settings(max_examples=10, deadline=None)
@given(depth=st.integers(1, 4), branch=st.integers(1, 5))
def test_taskspec_counts(depth, branch):
    """Property: count/total_work agree with an independent recursion."""
    def build(d):
        kids = [build(d - 1) for _ in range(branch)] if d else []
        return TaskSpec(work_pre=1.0, work_post=0.5, children=kids)
    root = build(depth)
    expect = sum(branch ** i for i in range(depth + 1))
    assert root.count() == expect
    assert root.total_work() == pytest.approx(1.5 * expect)


def test_paper_fft_scheduler_ordering():
    """Integration: the paper's FFT@16 ordering
    bf ≪ cilk ≤ wf < {wf,cilk}+NUMA ≤ DFWSPT/DFWSRPT."""
    wl = bots.fft(n=1 << 15, cutoff=4)
    spill0 = placement.first_touch_spill(TOPO, 0, 2)
    serial = serial_time(TOPO, wl, 0, spill0)

    def base(s):
        return simulate(TOPO, list(range(16)), wl, s, seed=0,
                        root_data_nodes=spill0, runtime_data_node=0,
                        migration_rate=0.15, serial_reference=serial).speedup

    alloc, spill = _numa_setup(16)

    def numa(s):
        return simulate(TOPO, alloc, wl, s, seed=0,
                        root_data_nodes=spill,
                        serial_reference=serial).speedup

    assert base("bf") < 0.5 * base("wf")
    assert numa("wf") > base("wf")
    assert max(numa("dfwspt"), numa("dfwsrpt")) >= numa("wf") * 0.98
