"""Locality-aware MoE routing tests (the paper's scheduler, in-graph)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import topology
from repro.core.routing import (RoutingConfig, expert_steal_table, route,
                                dispatch_combine_weights)

TOPO = topology.tpu_pod_2d(4, 4)
TABLE = expert_steal_table(TOPO, np.arange(16), "dfwspt")


def _logits(t=128, e=16, skew=None, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    if skew is not None:
        x = x.at[:, skew].add(3.0)
    return x


def test_steal_table_sorted_by_distance():
    d = TOPO.core_distance_matrix()
    for e in range(16):
        hops = [d[e, v] for v in TABLE[e]]
        assert hops == sorted(hops)
        assert set(TABLE[e].tolist()) == set(range(16)) - {e}


def test_dfwsrpt_randomizes_ties_only():
    t1 = expert_steal_table(TOPO, np.arange(16), "dfwsrpt", seed=0)
    t2 = expert_steal_table(TOPO, np.arange(16), "dfwsrpt", seed=1)
    d = TOPO.core_distance_matrix()
    for e in range(16):
        assert [d[e, v] for v in t1[e]] == [d[e, v] for v in t2[e]]
    assert (t1 != t2).any()        # ties actually shuffled


def test_no_overflow_no_steals():
    cfg = RoutingConfig(16, top_k=1, capacity=128, steal_attempts=3)
    logits = _logits()
    r = route(logits, cfg, TABLE)
    top1 = jnp.argmax(logits, axis=1)
    np.testing.assert_array_equal(np.asarray(r["expert"][:, 0]),
                                  np.asarray(top1))
    assert float(r["drop_fraction"]) == 0.0


def test_stealing_reduces_drops():
    skewed = _logits(skew=[0, 1])
    base = route(skewed, RoutingConfig(16, 1, 16, steal_attempts=0))
    stolen = route(skewed, RoutingConfig(16, 1, 16, steal_attempts=3),
                   TABLE)
    assert float(stolen["drop_fraction"]) < float(base["drop_fraction"])


def test_capacity_never_exceeded():
    cfg = RoutingConfig(16, top_k=2, capacity=8, steal_attempts=2)
    r = route(_logits(t=256, seed=1), cfg, TABLE)
    e = np.asarray(r["expert"]).ravel()
    s = np.asarray(r["slot"]).ravel()
    for ex in range(16):
        slots = s[e == ex]
        assert len(slots) <= 8
        assert len(set(slots.tolist())) == len(slots)   # unique slots
        assert (slots < 8).all() and (slots >= 0).all()


def test_weights_normalized_over_kept():
    cfg = RoutingConfig(16, top_k=4, capacity=4, steal_attempts=1)
    r = route(_logits(t=200, seed=2), cfg, TABLE)
    w = np.asarray(r["weight"])
    kept = np.asarray(r["expert"]) >= 0
    sums = w.sum(-1)
    has_any = kept.any(-1)
    np.testing.assert_allclose(sums[has_any], 1.0, rtol=1e-5)
    assert (w[~kept] == 0).all()


def test_stolen_tokens_go_to_nearest_free():
    """All overflow from expert 0 must land on its steal-order prefix."""
    cfg = RoutingConfig(16, top_k=1, capacity=8, steal_attempts=1)
    logits = jnp.full((32, 16), -5.0).at[:, 0].set(5.0)
    r = route(logits, cfg, TABLE)
    e = np.asarray(r["expert"][:, 0])
    moved = e[(e >= 0) & (e != 0)]
    assert set(moved.tolist()) <= {int(TABLE[0, 0])}
    assert (e == 0).sum() == 8     # expert 0 exactly at capacity


def test_dispatch_combine_consistency():
    cfg = RoutingConfig(8, top_k=2, capacity=16, steal_attempts=1)
    tbl = expert_steal_table(TOPO, np.arange(8) * 2, "dfwspt")
    r = route(_logits(t=64, e=8, seed=3), cfg, tbl)
    d, c = dispatch_combine_weights(r, 8, 16)
    # each (expert, slot) column holds at most one token
    assert (np.asarray(d).sum(axis=0) <= 1).all()
    # combine weights sit exactly where dispatch is true
    assert ((np.asarray(c) > 0) <= np.asarray(d)).all()


@settings(max_examples=15, deadline=None)
@given(t=st.sampled_from([32, 64]), k=st.integers(1, 3),
       cap=st.sampled_from([4, 8, 32]), attempts=st.integers(0, 3),
       seed=st.integers(0, 5))
def test_routing_invariants_property(t, k, cap, attempts, seed):
    cfg = RoutingConfig(16, top_k=k, capacity=cap, steal_attempts=attempts)
    r = route(_logits(t=t, seed=seed), cfg, TABLE)
    e = np.asarray(r["expert"])
    s = np.asarray(r["slot"])
    # dropped ⇔ slot == -1
    assert ((e < 0) == (s < 0)).all()
    # total kept ≤ total capacity
    assert (e >= 0).sum() <= 16 * cap
    # per-(expert, slot) uniqueness
    pairs = [(int(a), int(b)) for a, b in zip(e.ravel(), s.ravel())
             if a >= 0]
    assert len(pairs) == len(set(pairs))
    assert np.isfinite(float(r["aux_loss"]))
