"""Per-kernel sweeps vs the pure-jnp oracles (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(shape, dtype, k, scale=1.0):
    return (jax.random.normal(k, shape) * scale).astype(dtype)


# ----------------------------------------------------------------------
# rmsnorm
# ----------------------------------------------------------------------

@pytest.mark.parametrize("rows,d", [(64, 128), (256, 512), (31, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_shapes(rows, d, dtype):
    k1, k2 = jax.random.split(KEY)
    x = _rand((rows, d), dtype, k1)
    w = _rand((d,), dtype, k2)
    got = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_grad_matches_ref():
    x = _rand((128, 64), jnp.float32, KEY)
    w = jnp.ones((64,))
    g1 = jax.grad(lambda x: ops.rmsnorm(x, w).sum())(x)
    g2 = jax.grad(lambda x: ref.rmsnorm_ref(x, w).sum())(x)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("S,Hq,Hkv,D,causal", [
    (128, 4, 4, 32, True),       # MHA causal
    (256, 8, 2, 64, True),       # GQA causal
    (256, 8, 2, 64, False),      # bidirectional (encoder)
    (128, 6, 3, 48, True),       # non-pow2 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(S, Hq, Hkv, D, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand((2, S, Hq, D), dtype, ks[0])
    k = _rand((2, S, Hkv, D), dtype, ks[1])
    v = _rand((2, S, Hkv, D), dtype, ks[2])
    got = ops.flash_attention(q, k, v, causal=causal,
                              block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_window():
    ks = jax.random.split(KEY, 3)
    q = _rand((1, 256, 4, 32), jnp.float32, ks[0])
    k = _rand((1, 256, 4, 32), jnp.float32, ks[1])
    v = _rand((1, 256, 4, 32), jnp.float32, ks[2])
    got = ops.flash_attention(q, k, v, causal=True, window=64)
    want = ref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(off=st.integers(0, 192))
def test_flash_decode_offsets(off):
    """Property: decode (Sq=1) matches ref at any cache offset."""
    ks = jax.random.split(jax.random.PRNGKey(off), 3)
    q = _rand((2, 1, 4, 32), jnp.float32, ks[0])
    k = _rand((2, 256, 2, 32), jnp.float32, ks[1])
    v = _rand((2, 256, 2, 32), jnp.float32, ks[2])
    got = ops.flash_attention(q, k, v, causal=True, kv_offset=off,
                              block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, kv_offset=off)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


def test_chunked_ref_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = _rand((1, 512, 4, 32), jnp.float32, ks[0])
    k = _rand((1, 512, 2, 32), jnp.float32, ks[1])
    v = _rand((1, 512, 2, 32), jnp.float32, ks[2])
    got = ref.attention_chunked_ref(q, k, v, causal=True, chunk=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


# ----------------------------------------------------------------------
# ssd scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("S,H,P,G,N,chunk", [
    (128, 2, 16, 1, 8, 32),
    (256, 4, 32, 2, 16, 64),
    (64, 2, 16, 2, 8, 64),       # chunk == S
])
def test_ssd_kernel_vs_ref(S, H, P, G, N, chunk):
    ks = jax.random.split(KEY, 4)
    x = _rand((2, S, H, P), jnp.float32, ks[0], 0.5)
    a = -jnp.abs(_rand((2, S, H), jnp.float32, ks[1], 0.3))
    b = _rand((2, S, G, N), jnp.float32, ks[2], 0.3)
    c = _rand((2, S, G, N), jnp.float32, ks[3], 0.3)
    y1, h1 = ops.ssd_scan(x, a, b, c, chunk=chunk)
    y2, h2 = ref.ssd_ref(x, a, b, c, return_state=True)
    np.testing.assert_allclose(y1, y2, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-3, atol=2e-4)


def test_ssd_chunked_ref_with_state():
    """Chunked dual form == sequential scan, including carried state."""
    ks = jax.random.split(KEY, 5)
    x = _rand((1, 128, 2, 16), jnp.float32, ks[0], 0.5)
    a = -jnp.abs(_rand((1, 128, 2), jnp.float32, ks[1], 0.3))
    b = _rand((1, 128, 1, 8), jnp.float32, ks[2], 0.3)
    c = _rand((1, 128, 1, 8), jnp.float32, ks[3], 0.3)
    h0 = _rand((1, 2, 8, 16), jnp.float32, ks[4], 0.2)
    y1, h1 = ref.ssd_chunked_ref(x, a, b, c, h0=h0, chunk=32,
                                 return_state=True)
    y2, h2 = ref.ssd_ref(x, a, b, c, h0=h0, return_state=True)
    np.testing.assert_allclose(y1, y2, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-3, atol=2e-4)


def test_ssd_decode_continuity():
    """State from prefill + single-step decode == full-sequence run."""
    ks = jax.random.split(KEY, 4)
    S = 96
    x = _rand((1, S, 2, 16), jnp.float32, ks[0], 0.5)
    a = -jnp.abs(_rand((1, S, 2), jnp.float32, ks[1], 0.3))
    b = _rand((1, S, 1, 8), jnp.float32, ks[2], 0.3)
    c = _rand((1, S, 1, 8), jnp.float32, ks[3], 0.3)
    y_full = ref.ssd_ref(x, a, b, c)
    _, h = ref.ssd_ref(x[:, :-1], a[:, :-1], b[:, :-1], c[:, :-1],
                       return_state=True)
    y_last = ref.ssd_ref(x[:, -1:], a[:, -1:], b[:, -1:], c[:, -1:], h0=h)
    np.testing.assert_allclose(y_last[:, 0], y_full[:, -1],
                               rtol=2e-4, atol=2e-5)


# ----------------------------------------------------------------------
# moe gmm
# ----------------------------------------------------------------------

@pytest.mark.parametrize("E,C,D,F", [(4, 128, 64, 128), (8, 64, 128, 64),
                                     (2, 100, 48, 72)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_vs_ref(E, C, D, F, dtype):
    k1, k2 = jax.random.split(KEY)
    x = _rand((E, C, D), dtype, k1)
    w = _rand((E, D, F), dtype, k2)
    got = ops.moe_gmm(x, w, block_c=64, block_f=64, block_d=32)
    want = ref.moe_gmm_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_moe_gmm_grads():
    k1, k2 = jax.random.split(KEY)
    x = _rand((2, 64, 32), jnp.float32, k1)
    w = _rand((2, 32, 64), jnp.float32, k2)
    g1 = jax.grad(lambda w: ops.moe_gmm(x, w).sum())(w)
    g2 = jax.grad(lambda w: ref.moe_gmm_ref(x, w).sum())(w)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)
