"""Tests for the durable sweep layer: store, resume, timeout, retry.

The tentpole contract: a journaled grid run interrupted at any point
(even SIGKILL mid-batch) resumes bit-identically to an uninterrupted
run, re-simulating only the incomplete cells; a fully warm store
replays a grid without invoking either engine; a hung or killed worker
is killed/respawned by the supervisor without stalling sibling cells;
transient failures retry down the C → py → recorded-failure ladder.
"""

import json
import os
import signal
import time

import pytest

from repro.core import topology
from repro.core.sim import (CellError, CellTimeout, Machine, ResultStore,
                            RetryPolicy, SimParams, SimResult, WorkerDied,
                            bots, cell_key, policy, reset_engine_cache,
                            resolve_timeout, workload_fingerprint)
from repro.core.sim import _csim, _engine_py

TOPO = topology.sunfire_x4600()
HAVE_C = _csim.load() is not None
ENGINES = ["py", "c"] if HAVE_C else ["py"]


@pytest.fixture(params=ENGINES)
def engine(request, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", request.param)
    reset_engine_cache()
    yield request.param
    reset_engine_cache()


def _wl():
    return bots.fft(n=1 << 10, cutoff=8)


def _grid(machine, wl, seeds=3):
    return machine.grid(workloads=[wl], schedulers=("wf", "dfwsrpt"),
                        threads=(4, 16), seeds=seeds,
                        faults=[None, "straggler:1.0"])


# ----------------------------------------------------------------------
# fingerprints and keys
# ----------------------------------------------------------------------

def test_fingerprints_stable_and_content_addressed():
    t1 = topology.sunfire_x4600()
    t2 = topology.sunfire_x4600()
    assert t1.fingerprint() == t2.fingerprint()
    # the name is excluded: physically identical machines collide
    import dataclasses
    t3 = dataclasses.replace(t1, name="renamed")
    assert t3.fingerprint() == t1.fingerprint()
    assert topology.uma(16).fingerprint() != t1.fingerprint()

    w1, w2 = _wl(), _wl()
    assert workload_fingerprint(w1) == workload_fingerprint(w2)
    w2.name = "renamed"
    assert workload_fingerprint(w1) == workload_fingerprint(w2)
    assert workload_fingerprint(bots.fft(n=1 << 11, cutoff=8)) \
        != workload_fingerprint(w1)


def test_cell_key_discriminates():
    m = Machine(TOPO)
    wl = _wl()
    ectx = m.context(16)
    spec = policy.get_spec("wf")
    k = cell_key(ectx, wl, spec, 0, 100.0)
    assert k == cell_key(ectx, wl, spec, 0, 100.0)
    assert k != cell_key(ectx, wl, spec, 1, 100.0)          # seed
    assert k != cell_key(ectx, wl, spec, 0, 101.0)          # serial ref
    assert k != cell_key(ectx, wl, policy.get_spec("bf"), 0, 100.0)
    assert k != cell_key(m.context(8), wl, spec, 0, 100.0)  # context
    assert k != cell_key(m.context(16, faults="straggler:1.0"), wl,
                         spec, 0, 100.0)                    # faults
    # params affect results -> must affect the key (workers must not)
    m2 = Machine(TOPO, SimParams(steal_time=9.0))
    assert k != cell_key(m2.context(16), wl, spec, 0, 100.0)
    m3 = Machine(TOPO, SimParams(workers=4))
    assert k == cell_key(m3.context(16), wl, spec, 0, 100.0)


# ----------------------------------------------------------------------
# store roundtrip
# ----------------------------------------------------------------------

def test_store_roundtrip_exact(tmp_path, engine):
    m = Machine(TOPO)
    grid = _grid(m, _wl())
    base = grid.run(workers=1)
    path = tmp_path / "j.jsonl"
    assert grid.run(workers=1, store=str(path)) == base
    # reload from disk: every field bit-exact (floats via repr round-trip)
    st = ResultStore(path)
    assert len(st) == len(base)
    replay = grid.run(workers=1, store=st)
    assert st.hits == len(base)
    for k in base:
        assert replay[k] == base[k]
        assert replay[k].makespan == base[k].makespan     # exact floats
        assert replay[k].speedup == base[k].speedup
        assert replay[k].engine == engine                 # provenance kept
    st.close()


def test_store_tolerates_torn_tail(tmp_path, engine):
    m = Machine(TOPO)
    grid = _grid(m, _wl())
    base = grid.run(workers=1)
    path = tmp_path / "j.jsonl"
    grid.run(workers=1, store=str(path))
    raw = path.read_bytes()
    # tear the journal mid-final-line, as a SIGKILL mid-commit would
    path.write_bytes(raw[:-17])
    with pytest.warns(RuntimeWarning, match="torn final line"):
        st = ResultStore(path)
    assert len(st) == len(base) - 1
    # resuming completes the missing cell and matches bit for bit
    assert grid.run(workers=1, store=st) == base
    st.close()
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")            # repaired: loads clean now
        st2 = ResultStore(path)
    assert len(st2) == len(base)
    st2.close()


def test_store_header_and_first_write_wins(tmp_path):
    path = tmp_path / "j.jsonl"
    st = ResultStore(path)
    r1 = SimResult(makespan=1.0, serial_time=2.0, speedup=2.0, tasks=3,
                   steals=0, failed_probes=0, remote_work_fraction=0.0,
                   queue_wait=0.0, engine="c")
    r2 = SimResult(makespan=9.0, serial_time=2.0, speedup=2.0 / 9, tasks=3,
                   steals=0, failed_probes=0, remote_work_fraction=0.0,
                   queue_wait=0.0)
    st.put("k1", r1)
    st.put("k1", r2)                        # no-op: first write wins
    assert st.get("k1") == r1
    st.close()
    lines = path.read_text().splitlines()
    assert json.loads(lines[0]) == {"format": "repro-sim-store",
                                    "version": 1}
    assert len(lines) == 2                  # header + one entry


# ----------------------------------------------------------------------
# resume bit-identity; warm store never invokes an engine
# ----------------------------------------------------------------------

def test_interrupted_resume_bit_identical(tmp_path, engine, monkeypatch):
    """Truncate a journal to simulate an interrupted campaign; the
    resumed run matches the uninterrupted one and re-simulates only the
    missing cells."""
    m = Machine(TOPO)
    grid = _grid(m, _wl())
    base = grid.run(workers=1)
    path = tmp_path / "j.jsonl"
    grid.run(workers=1, store=str(path))
    lines = path.read_text().splitlines(keepends=True)
    keep = len(lines) // 2
    path.write_text("".join(lines[:keep]))

    calls = []
    mod = _csim if engine == "c" else _engine_py
    orig = mod.run_batch

    def counting(ctxs, workers=1):
        calls.append(len(list(ctxs)))
        return orig(ctxs, workers=workers)

    monkeypatch.setattr(mod, "run_batch", counting)
    resumed = grid.run(workers=1, resume=str(path))
    assert resumed == base
    assert sum(calls) == len(base) - (keep - 1)   # only incomplete cells


def test_warm_store_never_invokes_engine(tmp_path, engine, monkeypatch):
    m = Machine(TOPO)
    grid = _grid(m, _wl())
    path = tmp_path / "j.jsonl"
    base = grid.run(workers=1, store=str(path))

    def boom(*a, **kw):
        raise AssertionError("engine invoked on a fully warm store")

    monkeypatch.setattr(_csim, "run_batch", boom)
    monkeypatch.setattr(_csim, "run", boom)
    monkeypatch.setattr(_engine_py, "run_batch", boom)
    monkeypatch.setattr(_engine_py, "run", boom)
    assert grid.run(workers=1, store=str(path)) == base


def test_machine_run_through_store(tmp_path, engine):
    m = Machine(TOPO)
    wl = _wl()
    st = ResultStore(tmp_path / "cells.jsonl")
    r1 = m.run(wl, "wf", seed=0, threads=16, store=st)
    direct = m.run(wl, "wf", seed=0, threads=16)
    assert r1 == direct
    assert len(st) == 1
    r2 = m.run(wl, "wf", seed=0, threads=16, store=st)
    assert r2 == r1 and st.hits == 1
    st.close()


# ----------------------------------------------------------------------
# wall-clock timeout: hung cells killed, siblings unaffected
# ----------------------------------------------------------------------

def test_hung_cell_times_out_without_stalling_siblings(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=4)
    base = grid.run(workers=1)
    orig = _engine_py.run

    def hang(ctx):
        if ctx["seed"] == 1:
            time.sleep(3600)
        return orig(ctx)

    monkeypatch.setattr(_engine_py, "run", hang)
    t0 = time.monotonic()
    res = grid.run(strict=False, workers=2, timeout=2.0)
    assert time.monotonic() - t0 < 60
    reset_engine_cache()
    vals = list(res.items())
    errs = [(k, v) for k, v in vals if isinstance(v, CellError)]
    assert len(errs) == 1
    k, err = errs[0]
    assert k.seed == 1
    assert isinstance(err.error, CellTimeout)
    assert err.engine == "py"
    assert "wall-clock timeout" in str(err.error)
    for k, v in vals:
        if isinstance(v, SimResult):
            assert v == base[k]               # siblings bit-exact


def test_timeout_env_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SIM_TIMEOUT", raising=False)
    assert resolve_timeout() is None
    assert resolve_timeout(5) == 5.0
    assert resolve_timeout(0) is None         # 0 disables
    monkeypatch.setenv("REPRO_SIM_TIMEOUT", "2.5")
    assert resolve_timeout() == 2.5
    assert resolve_timeout(9) == 9.0          # explicit beats env
    monkeypatch.setenv("REPRO_SIM_TIMEOUT", "nope")
    with pytest.raises(ValueError, match="REPRO_SIM_TIMEOUT"):
        resolve_timeout()


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_supervised_path_matches_c_engine(monkeypatch):
    """With a timeout set, C cells run inside killable fork workers —
    results still bit-identical to the in-process C batch."""
    monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
    reset_engine_cache()
    m = Machine(TOPO)
    grid = _grid(m, _wl())
    base = grid.run(workers=1)
    assert grid.run(workers=2, timeout=120.0) == base
    reset_engine_cache()


# ----------------------------------------------------------------------
# worker death: SIGKILL mid-batch -> respawn, retry completes the batch
# ----------------------------------------------------------------------

def test_sigkilled_worker_respawned_and_batch_completes(
        tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=4)
    base = grid.run(workers=1)
    orig = _engine_py.run
    flag = tmp_path / "killed-once"

    def die_once(ctx):
        if ctx["seed"] == 2 and not flag.exists():
            flag.touch()
            os.kill(os.getpid(), signal.SIGKILL)   # fork worker suicide
        return orig(ctx)

    monkeypatch.setattr(_engine_py, "run", die_once)
    res = grid.run(strict=False, workers=2, timeout=120.0,
                   retry=RetryPolicy(backoff=0.0))
    reset_engine_cache()
    assert flag.exists()
    assert all(isinstance(v, SimResult) for v in res.values())
    assert res == base


def test_worker_death_without_retry_is_recorded(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=3)
    orig = _engine_py.run

    def die(ctx):
        if ctx["seed"] == 1:
            os.kill(os.getpid(), signal.SIGKILL)
        return orig(ctx)

    monkeypatch.setattr(_engine_py, "run", die)
    res = grid.run(strict=False, workers=2, timeout=120.0)
    reset_engine_cache()
    errs = [v for v in res.values() if isinstance(v, CellError)]
    assert len(errs) == 1
    assert isinstance(errs[0].error, WorkerDied)
    assert sum(isinstance(v, SimResult) for v in res.values()) == 2


# ----------------------------------------------------------------------
# retry policy and the degradation ladder
# ----------------------------------------------------------------------

def test_transient_failure_retried(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=2)
    base = grid.run(workers=1)
    orig = _engine_py.run_batch
    fails = {"left": 1}

    def flaky(ctxs, workers=1):
        outs = orig(ctxs, workers=workers)
        if fails["left"]:
            fails["left"] -= 1
            outs[0] = MemoryError("transient pressure")
        return outs

    monkeypatch.setattr(_engine_py, "run_batch", flaky)
    res = grid.run(workers=1, retry=RetryPolicy(backoff=0.0))
    reset_engine_cache()
    assert res == base                        # retried cell bit-exact
    assert fails["left"] == 0


def test_deterministic_failure_not_retried(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=1)
    calls = {"n": 0}
    orig = _engine_py.run_batch

    def boom(ctxs, workers=1):
        calls["n"] += 1
        return [ValueError("deterministic bug") for _ in ctxs]

    monkeypatch.setattr(_engine_py, "run_batch", boom)
    res = grid.run(strict=False, workers=1,
                   retry=RetryPolicy(retries=5, backoff=0.0))
    reset_engine_cache()
    err = next(iter(res.values()))
    assert isinstance(err, CellError)
    assert calls["n"] == 1                    # no retries
    assert len(err.attempts) == 1


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_degradation_ladder_c_to_py(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
    reset_engine_cache()
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=2)
    base = grid.run(workers=1)

    def oom(ctxs, workers=1):
        return [MemoryError("sim_run: allocation failed") for _ in ctxs]

    monkeypatch.setattr(_csim, "run_batch", oom)
    res = grid.run(strict=False, workers=1, retry=RetryPolicy(backoff=0.0))
    reset_engine_cache()
    assert all(isinstance(v, SimResult) for v in res.values())
    assert res == base                        # py replays C bit-exactly
    assert {v.engine for v in res.values()} == {"py"}


@pytest.mark.skipif(not HAVE_C, reason="C kernel unavailable")
def test_exhausted_ladder_records_attempt_trail(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "c")
    reset_engine_cache()

    def oom(ctxs, workers=1):
        return [MemoryError("oom") for _ in ctxs]

    monkeypatch.setattr(_csim, "run_batch", oom)
    monkeypatch.setattr(_engine_py, "run_batch", oom)
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=1)
    res = grid.run(strict=False, workers=1,
                   retry=RetryPolicy(retries=2, backoff=0.0))
    reset_engine_cache()
    err = next(iter(res.values()))
    assert isinstance(err, CellError)
    assert [e for e, _ in err.attempts] == ["c", "py", "py"]
    assert err.engine == "py"
    r = repr(err)
    assert "3 attempts" in r and "c: MemoryError" in r


# ----------------------------------------------------------------------
# CellError provenance: engine + remote traceback
# ----------------------------------------------------------------------

def test_cellerror_carries_engine_and_remote_traceback(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_ENGINE", "py")
    reset_engine_cache()
    orig = _engine_py.run

    def boom(ctx):
        if ctx["seed"] == 1:
            raise ValueError("injected failure")
        return orig(ctx)

    monkeypatch.setattr(_engine_py, "run", boom)
    m = Machine(TOPO)
    wl = _wl()
    grid = m.grid(workloads=[wl], schedulers=("wf",), threads=16, seeds=2)
    res = grid.run(strict=False, workers=2)   # fork pool path
    reset_engine_cache()
    err = res[next(k for k in grid.keys if k.seed == 1)]
    assert isinstance(err, CellError)
    assert err.engine == "py"
    assert "injected failure" in err.traceback
    assert "boom" in err.traceback            # the remote frame is there
    assert "[py]" in repr(err)


def test_cellerror_legacy_positional_construction():
    e = CellError("cell", 0, ValueError("x"))
    assert e.engine == "" and e.attempts == () and e.traceback == ""
    assert repr(e) == "CellError('cell': ValueError: x)"
