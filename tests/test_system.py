"""End-to-end behaviour tests: training convergence, checkpoint/restart
equivalence, serving loop, sharding engine fit rules, dry-run cell
plumbing (single-device)."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import PipelineConfig, TokenPipeline
from repro.launch import train as train_mod
from repro.launch import serve as serve_mod
from repro.models import model
from repro.optim import AdamWConfig, adamw_init, adamw_update


@pytest.mark.slow
def test_training_reduces_loss_end_to_end():
    """A tiny LM must overfit the deterministic synthetic stream."""
    loss = train_mod.main([
        "--arch", "stablelm-1.6b", "--reduced", "--steps", "60",
        "--global-batch", "8", "--seq-len", "32", "--lr", "3e-3",
        "--warmup", "10", "--log-every", "30"])
    # well below ln(V) = ln(256) ≈ 5.55 after 60 steps
    assert loss < 5.0


@pytest.mark.slow
def test_checkpoint_restart_bitwise_resume():
    """Stop at step k, restart, and land on the same loss trajectory."""
    cfg = configs.get("qwen2.5-3b").reduced()
    pipe = TokenPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                        seq_len=16, global_batch=4, seed=5))
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=30)

    def _step(p, o, b):
        (l, _), g = jax.value_and_grad(
            lambda pp: model.train_loss(pp, cfg, b), has_aux=True)(p)
        p, o, _ = adamw_update(g, o, p, opt_cfg)
        return p, o, l

    step_fn = jax.jit(_step)

    def run(start, steps, params, opt):
        losses = []
        for s in range(start, start + steps):
            b = pipe.batch_at(s)
            params, opt, l = step_fn(params, opt, b)
            losses.append(float(l))
        return params, opt, losses

    params = model.init_params(cfg, jax.random.PRNGKey(1))
    opt = adamw_init(params, opt_cfg)

    # uninterrupted run
    _, _, ref_losses = run(0, 10, params, opt)

    # interrupted at 6 + resume from checkpoint
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        p2, o2, l_a = run(0, 6, params, opt)
        mgr.save_sync(6, {"params": p2, "opt": o2})
        step, tree = mgr.restore_latest({"params": p2, "opt": o2})
        assert step == 6
        _, _, l_b = run(6, 4, tree["params"], tree["opt"])
    np.testing.assert_allclose(l_a + l_b, ref_losses, rtol=2e-4, atol=2e-5)


def test_serving_driver_runs():
    gen = serve_mod.main(["--arch", "qwen2.5-3b", "--reduced",
                          "--batch", "2", "--prompt-len", "16",
                          "--gen", "4"])
    assert gen.shape == (2, 4)
    assert (gen >= 0).all() and (gen < 256).all()


@pytest.mark.slow
def test_moe_arch_trains_with_steal_table():
    loss = train_mod.main([
        "--arch", "granite-moe-1b-a400m", "--reduced", "--steps", "30",
        "--global-batch", "4", "--seq-len", "32", "--lr", "2e-3",
        "--warmup", "5", "--log-every", "15"])
    assert np.isfinite(loss) and loss < 5.55


# ----------------------------------------------------------------------
# sharding rules engine (pure functions — no extra devices needed)
# ----------------------------------------------------------------------

class _FakeMesh:
    """Duck-typed mesh for fit_spec tests."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fit_spec_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    from repro.launch.shardings import fit_spec
    mesh = _FakeMesh({"data": 16, "model": 16})
    # divisible → kept
    assert tuple(fit_spec(mesh, (256, 512), P("data", "model"))) == \
        ("data", "model")
    # non-divisible dim → replicated
    assert tuple(fit_spec(mesh, (40, 512), P("model", "data"))) == \
        (None, "data")


def test_fit_spec_trailing_none_trimmed():
    from jax.sharding import PartitionSpec as P
    from repro.launch.shardings import fit_spec
    mesh = _FakeMesh({"data": 4})
    p = fit_spec(mesh, (8, 3, 5), P("data", None, None))
    assert tuple(p) == ("data",)


def test_input_specs_cover_every_cell():
    from repro.launch import dryrun
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for shape in cfg.shapes():
            sds = dryrun.input_specs(arch, shape)
            assert isinstance(sds, dict) and sds
            for v in jax.tree.leaves(sds):
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_skipped_cells_documented():
    total = 0
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        run = set(cfg.shapes())
        skip = set(cfg.skipped_shapes())
        assert run.isdisjoint(skip)
        assert run | skip == set(configs.SHAPES)
        total += len(run)
    assert total == 31      # 40 cells − 9 documented skips
