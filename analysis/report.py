"""One-command execution forensics over the paper sweep.

    PYTHONPATH=src python -m analysis.report [--quick]
        [--engine {py,c,both}] [--seeds N] [--out DIR] [--workers N]
        [--store PATH] [--from-store PATH]

Runs the traced figure sweep (:func:`benchmarks.bots_repro.
forensics_plan` — scheduler study + thread-allocation study, paper-
scale FFT included; ``--quick`` is the fft-small CI smoke), then:

* regenerates the **paper figure set** — speedup-vs-threads lines for
  Figs 13–15, baseline-vs-NUMA bars for Figs 5–10 — from the sweep's
  ``SimResult`` metrics;
* renders the **forensics set** from the event traces — steal-distance
  heatmap, per-node locality scores, queue-depth timelines, per-thread
  Gantt charts — plus ``steals.csv`` (tidy event export) and
  ``forensics.json`` (headline stats per cell);
* under ``--engine both`` runs the sweep on *both* engines and asserts
  results **and traces** are identical cell-for-cell before rendering.

``--store`` journals the sweep durably (traces spill to sidecars);
``--from-store`` skips simulation and analyzes an existing journal's
sidecar traces instead.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from . import figures, frames, loader, stats

DEFAULT_OUT = os.path.join("artifacts", "analysis")


def _mean_ci(xs) -> "tuple[float, float]":
    a = np.asarray(list(xs), dtype=float)
    if len(a) < 2:
        return float(a.mean()), 0.0
    return float(a.mean()), float(1.96 * a.std(ddof=1) / np.sqrt(len(a)))


def _run_sweep(engine, quick, seeds, store, workers):
    """The traced forensics sweep under one engine (None: current)."""
    from repro.core.sim import reset_engine_cache
    from benchmarks import bots_repro
    prev = os.environ.get("REPRO_SIM_ENGINE")
    if engine:
        os.environ["REPRO_SIM_ENGINE"] = engine
        reset_engine_cache()
    try:
        machine = bots_repro.traced_machine()
        grid, info = bots_repro.forensics_plan(
            machine, quick=quick, seeds=seeds, store=store)
        return grid.run(workers=workers), info
    finally:
        if engine:
            if prev is None:
                os.environ.pop("REPRO_SIM_ENGINE", None)
            else:
                os.environ["REPRO_SIM_ENGINE"] = prev
            reset_engine_cache()


def _check_parity(res_a, res_b) -> int:
    """Cell-for-cell py↔C equality of results *and* event traces."""
    bad = 0
    for k, ra in res_a.items():
        rb = res_b[k]
        if ra != rb or ra.trace != rb.trace:
            bad += 1
            print(f"PARITY FAILURE at {loader.label_for(k)}",
                  file=sys.stderr)
    return bad


def _paper_figures(res, info, out) -> "list[str]":
    """Figs 13–15 lines + Figs 5–10 bars from the sweep's metrics."""
    from benchmarks.bots_repro import ALLOC_SCHEDS, STUDY_SCHEDS
    threads, seeds = info["threads"], info["seeds"]
    top = threads[-1]
    study = {}
    for wl in info["study"]:
        per = {}
        for sched in STUDY_SCHEDS:
            ms, cis = [], []
            for T in threads:
                m, ci = _mean_ci(res[(wl, sched, "numa", T, s, "none")].speedup
                                 for s in seeds)
                ms.append(m)
                cis.append(ci)
            per[sched] = (list(threads), ms, cis)
        study[wl] = per
    paths = figures.speedup_lines(study, out)
    alloc = {}
    for wl in info["alloc"]:
        per = {}
        for sched in ALLOC_SCHEDS:
            base, _ = _mean_ci(res[(wl, sched, "base", top, s, "none")].speedup
                               for s in seeds)
            numa, _ = _mean_ci(res[(wl, sched, "numa", top, s, "none")].speedup
                               for s in seeds)
            per[sched] = (base, numa)
        alloc[wl] = per
    paths.append(figures.variant_gain_bars(
        alloc, os.path.join(out, "fig5_10_threadalloc.png"), top))
    return paths


def _forensics_figures(records, out, gantt_of=()) -> "list[str]":
    """The trace diagnostics shared by the sweep and journal paths."""
    traced = [r for r in records if r.trace is not None]
    if not traced:
        return []
    paths = []
    hists = {r.label: stats.steal_hist(r) for r in traced}
    width = max(len(h) for h in hists.values())
    hists = {lbl: stats.steal_hist(r, max_hop=width - 1)
             for lbl, r in zip(hists, traced)}
    paths.append(figures.steal_heatmap(
        hists, os.path.join(out, "steal_distance_heatmap.png")))
    paths.append(figures.locality_bars(
        {r.label: stats.locality(r)["score"] for r in traced},
        os.path.join(out, "node_locality.png")))
    paths.append(figures.queue_depth(
        {r.label: stats.queue_depth_timeline(r)[:2] for r in traced},
        os.path.join(out, "queue_depth.png")))
    for r in gantt_of:
        safe = r.label.replace("/", "_")
        paths.append(figures.gantt_chart(
            stats.gantt(r), os.path.join(out, f"gantt_{safe}.png"),
            title=f"Gantt: {r.label}",
            num_nodes=int(r.meta.get("num_nodes", 0)) or None))
    if frames.HAVE_PANDAS:
        df = frames.events_frame(traced, kind="steal")
        csv = os.path.join(out, "steals.csv")
        df.to_csv(csv, index=False)
        paths.append(csv)
    return paths


def run_forensics(quick: bool = False, engine: "str | None" = "both",
                  seeds=(0, 1), out: str = DEFAULT_OUT, store=None,
                  workers=None) -> dict:
    """Run the traced sweep and regenerate every figure; returns a
    summary dict (rows, figure paths, parity status)."""
    from repro.core.sim import _csim
    from benchmarks.bots_repro import STUDY_SCHEDS
    engines = [engine]
    parity = None
    if engine == "both":
        if _csim.load() is None:
            print("# --engine both: C kernel unavailable "
                  f"({_csim.load_error}); running py only")
            engines = ["py"]
        else:
            engines = ["c", "py"]
    t0 = time.perf_counter()
    res = info = None
    for eng in engines:
        r, info = _run_sweep(eng, quick, seeds, store, workers)
        if res is None:
            res = r            # figures come from the first engine
        else:
            bad = _check_parity(res, r)
            parity = bad == 0
            if bad:
                raise SystemExit(
                    f"{bad} cell(s) diverge between engines")
    os.makedirs(out, exist_ok=True)
    paths = _paper_figures(res, info, out)

    # forensic slice: the study workloads at the top thread count,
    # NUMA variant, first seed — the cells the paper's bars headline
    top, s0 = info["threads"][-1], info["seeds"][0]
    slice_keys = [k for k in res
                  if k.threads == top and k.context == "numa"
                  and k.seed == s0 and k.workload in info["study"]
                  and k.scheduler in STUDY_SCHEDS]
    records = [loader.from_result(res[k], loader.label_for(k))
               for k in slice_keys]
    gantt_of = [r for r in records
                if any(r.label.startswith(f"{info['study'][0]}/{s}/")
                       for s in ("wf", "dfwsrpt"))]
    paths += _forensics_figures(records, out, gantt_of=gantt_of)

    rows = []
    for r in records:
        row = dict(label=r.label)
        row.update(stats.summary(r))
        rows.append(row)
    summary = dict(
        quick=quick, engines=engines, parity=parity,
        cells=len(res), seconds=round(time.perf_counter() - t0, 2),
        out=out, figures=sorted(paths), rows=rows)
    with open(os.path.join(out, "forensics.json"), "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
    return summary


def report_from_store(path, out: str = DEFAULT_OUT) -> dict:
    """Analyze an existing durable-sweep journal's sidecar traces."""
    records = [r for r in loader.from_store(path) if r.trace is not None]
    if not records:
        raise SystemExit(f"no sidecar traces under {path!r} — run the "
                         "sweep with SimParams(trace=True) and store=")
    os.makedirs(out, exist_ok=True)
    paths = _forensics_figures(records, out, gantt_of=records[:1])
    rows = []
    for r in records:
        row = dict(label=r.label)
        row.update(stats.summary(r))
        rows.append(row)
    summary = dict(source=os.fspath(path), cells=len(records), out=out,
                   figures=sorted(paths), rows=rows)
    with open(os.path.join(out, "forensics.json"), "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(
        description="regenerate paper figures + trace forensics")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fft-small + sparselu, 1 seed")
    ap.add_argument("--engine", choices=("py", "c", "both"),
                    default="both",
                    help="engine(s); 'both' asserts trace parity")
    ap.add_argument("--seeds", type=int, default=None,
                    help="Monte-Carlo replicas per cell "
                         "(default: 1 quick / 2 full)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--store", default=None,
                    help="journal the sweep durably (traces spill to "
                         "<stem>.traces/ sidecars)")
    ap.add_argument("--from-store", default=None,
                    help="skip simulation; analyze this journal's "
                         "sidecar traces")
    args = ap.parse_args()

    if args.from_store:
        summary = report_from_store(args.from_store, out=args.out)
    else:
        n = args.seeds if args.seeds else (1 if args.quick else 2)
        store = None
        if args.store:
            from repro.core.sim import ResultStore
            store = ResultStore(args.store)
        summary = run_forensics(
            quick=args.quick, engine=args.engine,
            seeds=tuple(range(n)), out=args.out, store=store,
            workers=args.workers)
        if store is not None:
            store.close()

    for row in summary["rows"]:
        print(",".join(f"{k}={v}" for k, v in row.items()))
    if summary.get("parity") is not None:
        print(f"# parity: {'ok' if summary['parity'] else 'FAILED'} "
              f"({summary.get('cells')} cells x "
              f"{len(summary.get('engines', []))} engines)")
    print(f"# {len(summary['figures'])} artifacts -> {summary['out']}")


if __name__ == "__main__":
    main()
