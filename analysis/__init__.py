"""Execution forensics for the NUMA task-runtime simulator.

The simulator's aggregate metrics (makespan, steal count, remote
fraction) say *how well* a scheduler did; the event traces captured
under ``SimParams(trace=True)`` say *why*. This package is the analysis
pipeline on top of those traces:

* :mod:`analysis.loader`  — normalize trace sources (live
  :class:`~repro.core.sim.SimResult` values, sidecar ``.npz`` files,
  durable-sweep journals) into :class:`~analysis.loader.TraceRecord`.
* :mod:`analysis.frames`  — pandas DataFrames over the event columns
  (optional; everything else is pure numpy).
* :mod:`analysis.stats`   — steal-distance histograms, per-node
  locality scores, queue-depth timelines, per-thread utilization.
* :mod:`analysis.figures` — matplotlib renderings of the stats plus
  the paper's figure set (speedup bars/lines) from the same sweep.
* :mod:`analysis.report`  — the one-command driver::

      PYTHONPATH=src python -m analysis.report [--quick] [--engine both]

  runs a traced sweep (paper-scale FFT included), checks py↔C trace
  parity, and regenerates every figure under ``artifacts/analysis/``.
"""

from __future__ import annotations

from .loader import TraceRecord, from_grid, from_npz, from_result, \
    from_store
from . import stats

__all__ = ["TraceRecord", "from_grid", "from_npz", "from_result",
           "from_store", "stats"]
