"""Pandas views of event traces (optional layer).

The stats layer (:mod:`analysis.stats`) is pure numpy; this module is
the ad-hoc-exploration surface — tidy DataFrames you can group, join,
and pivot in a notebook, plus CSV export for the report driver. It
degrades gracefully: if pandas is not installed the module imports
fine and every frame constructor raises a clear ``ImportError``.
"""

from __future__ import annotations

try:
    import pandas as _pd
except ImportError:                    # pragma: no cover - env-dependent
    _pd = None

HAVE_PANDAS = _pd is not None

__all__ = ["HAVE_PANDAS", "exec_frame", "steal_frame", "mig_frame",
           "events_frame"]


def _pandas():
    if _pd is None:
        raise ImportError("analysis.frames needs pandas; install it or "
                          "use the numpy stats in analysis.stats")
    return _pd


def exec_frame(trace):
    """Exec events: task, thread, core, node, qlen, start, end, dur."""
    pd = _pandas()
    df = pd.DataFrame({
        "task": trace.ex_task, "thread": trace.ex_thread,
        "core": trace.ex_core, "node": trace.ex_node,
        "qlen": trace.ex_qlen, "start": trace.ex_start,
        "end": trace.ex_end})
    df["dur"] = df["end"] - df["start"]
    return df


def steal_frame(trace):
    """Steal events: time, thief, victim, task, hop distance."""
    pd = _pandas()
    return pd.DataFrame({
        "time": trace.st_time, "thief": trace.st_thief,
        "victim": trace.st_victim, "task": trace.st_task,
        "dist": trace.st_dist})


def mig_frame(trace):
    """Migration events: time, thread, from-core, to-core."""
    pd = _pandas()
    return pd.DataFrame({
        "time": trace.mg_time, "thread": trace.mg_thread,
        "from_core": trace.mg_from, "to_core": trace.mg_to})


def events_frame(records, kind: str = "steal"):
    """One tidy frame over many records, labeled per record.

    ``kind`` ∈ {"exec", "steal", "mig"}. Records without a trace are
    skipped (they contribute no events).
    """
    pd = _pandas()
    mk = {"exec": exec_frame, "steal": steal_frame,
          "mig": mig_frame}[kind]
    parts = []
    for rec in records:
        tr = getattr(rec, "trace", None)
        if tr is None:
            continue
        df = mk(tr)
        df.insert(0, "label", getattr(rec, "label", ""))
        parts.append(df)
    if not parts:
        return pd.DataFrame()
    return pd.concat(parts, ignore_index=True)
