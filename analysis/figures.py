"""Matplotlib renderings: paper figures + trace diagnostics.

Two figure families from the same traced sweep:

* the **paper set** — speedup-vs-threads lines for the scheduler study
  (Figs 13–15) and baseline-vs-NUMA allocation bars (Figs 5–10
  condensed to the T_max comparison the paper headlines);
* the **forensics set** — steal-distance heatmap, per-node locality
  scores, queue-depth timelines, and per-thread Gantt charts, none of
  which exist in the paper: they are the *why* behind its bars.

All renderers take plain arrays/dicts (produced by
:mod:`analysis.stats`) and write a PNG; matplotlib is imported lazily
with the Agg backend so the pipeline works headless.
"""

from __future__ import annotations

import os

import numpy as np

__all__ = ["steal_heatmap", "locality_bars", "queue_depth",
           "gantt_chart", "speedup_lines", "variant_gain_bars"]


def _plt():
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
    return plt


def _save(fig, path: str) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    fig.savefig(path, dpi=140, bbox_inches="tight")
    _plt().close(fig)
    return path


def steal_heatmap(hists: "dict[str, np.ndarray]", path: str,
                  title: str = "Steal distance") -> str:
    """Rows = runs, columns = hop distance, cell = steal count."""
    plt = _plt()
    labels = list(hists)
    width = max(len(h) for h in hists.values())
    m = np.zeros((len(labels), width))
    for i, lbl in enumerate(labels):
        h = hists[lbl]
        m[i, :len(h)] = h
    fig, ax = plt.subplots(
        figsize=(1.6 + 1.1 * width, 0.8 + 0.42 * len(labels)))
    im = ax.imshow(m, aspect="auto", cmap="viridis")
    ax.set_xticks(range(width))
    ax.set_xlabel("hop distance (0 = same node)")
    ax.set_yticks(range(len(labels)))
    ax.set_yticklabels(labels, fontsize=7)
    for i in range(len(labels)):
        for j in range(width):
            if m[i, j]:
                ax.text(j, i, f"{int(m[i, j])}", ha="center",
                        va="center", fontsize=6,
                        color="w" if m[i, j] < m.max() / 2 else "k")
    ax.set_title(title)
    fig.colorbar(im, ax=ax, label="steals")
    return _save(fig, path)


def locality_bars(scores: "dict[str, np.ndarray]", path: str,
                  title: str = "Per-node locality") -> str:
    """Grouped bars: one group per NUMA node, one bar per run; height
    = locality score (1.0 = no remote-access penalty on that node)."""
    plt = _plt()
    labels = list(scores)
    nn = max(len(s) for s in scores.values())
    fig, ax = plt.subplots(figsize=(1.5 + 0.55 * nn * len(labels), 3.2))
    w = 0.8 / max(len(labels), 1)
    x = np.arange(nn)
    for i, lbl in enumerate(labels):
        s = np.asarray(scores[lbl], dtype=float)
        s = np.pad(s, (0, nn - len(s)), constant_values=np.nan)
        ax.bar(x + (i - (len(labels) - 1) / 2) * w, s, w, label=lbl)
    ax.set_xticks(x)
    ax.set_xlabel("NUMA node")
    ax.set_ylabel("locality score")
    ax.set_ylim(0, 1.05)
    ax.set_title(title)
    ax.legend(fontsize=7)
    return _save(fig, path)


def queue_depth(series: "dict[str, tuple]", path: str,
                title: str = "Ready-queue depth") -> str:
    """Timelines: ``{label: (t, mean_depth)}`` on one axis."""
    plt = _plt()
    fig, ax = plt.subplots(figsize=(7, 3.2))
    for lbl, (t, depth) in series.items():
        ax.plot(t, depth, label=lbl, lw=1.1)
    ax.set_xlabel("simulated time")
    ax.set_ylabel("mean queue depth")
    ax.set_title(title)
    ax.legend(fontsize=7)
    return _save(fig, path)


def gantt_chart(intervals: "dict[int, tuple]", path: str,
                title: str = "Execution Gantt",
                num_nodes: "int | None" = None) -> str:
    """Per-thread ``broken_barh`` of exec intervals, colored by the
    NUMA node each interval ran on (``intervals`` from stats.gantt)."""
    plt = _plt()
    from matplotlib import cm
    from matplotlib.patches import Patch
    threads = sorted(intervals)
    nn = num_nodes or 1 + max(
        (int(nodes.max()) for _, _, nodes in intervals.values()
         if len(nodes)), default=0)
    cmap = cm.get_cmap("tab10" if nn <= 10 else "tab20")
    fig, ax = plt.subplots(figsize=(8, 0.6 + 0.3 * len(threads)))
    for row, th in enumerate(threads):
        starts, durs, nodes = intervals[th]
        ax.broken_barh(list(zip(starts, durs)), (row - 0.4, 0.8),
                       facecolors=[cmap(int(n) % cmap.N)
                                   for n in nodes], linewidth=0)
    ax.set_yticks(range(len(threads)))
    ax.set_yticklabels([f"t{th}" for th in threads], fontsize=7)
    ax.set_xlabel("simulated time")
    ax.set_title(title)
    ax.legend(handles=[Patch(color=cmap(n % cmap.N), label=f"node {n}")
                       for n in range(nn)], fontsize=6, ncol=min(nn, 8),
              loc="upper right")
    return _save(fig, path)


def speedup_lines(study: "dict[str, dict[str, tuple]]", outdir: str,
                  prefix: str = "fig13_15") -> "list[str]":
    """Scheduler-study lines (paper Figs 13–15): one figure per
    workload; ``study[workload][scheduler] = (threads, mean, ci95)``."""
    plt = _plt()
    paths = []
    for wl, per_sched in study.items():
        fig, ax = plt.subplots(figsize=(4.2, 3.2))
        for sched, (ts, mean, ci) in per_sched.items():
            ax.errorbar(ts, mean, yerr=ci, marker="o", ms=3,
                        capsize=2, lw=1.2, label=sched)
        ax.set_xlabel("threads")
        ax.set_ylabel("speedup")
        ax.set_title(f"{wl}: NUMA-aware schedulers")
        ax.legend(fontsize=7)
        paths.append(_save(
            fig, os.path.join(outdir, f"{prefix}_{wl}.png")))
    return paths


def variant_gain_bars(alloc: "dict[str, dict[str, tuple]]", path: str,
                      threads: int) -> str:
    """Thread-allocation study (paper Figs 5–10, condensed): for each
    benchmark × scheduler, baseline-Nanos vs NUMA-aware speedup at
    ``threads``; ``alloc[bench][sched] = (base_mean, numa_mean)``."""
    plt = _plt()
    benches = list(alloc)
    fig, axes = plt.subplots(
        1, len(benches), figsize=(2.1 * len(benches) + 1, 3.0),
        sharey=False)
    if len(benches) == 1:
        axes = [axes]
    for ax, bench in zip(axes, benches):
        scheds = list(alloc[bench])
        x = np.arange(len(scheds))
        base = [alloc[bench][s][0] for s in scheds]
        numa = [alloc[bench][s][1] for s in scheds]
        ax.bar(x - 0.2, base, 0.4, label="baseline")
        ax.bar(x + 0.2, numa, 0.4, label="NUMA-aware")
        ax.set_xticks(x)
        ax.set_xticklabels(scheds, fontsize=7)
        ax.set_title(bench, fontsize=8)
    axes[0].set_ylabel(f"speedup @ {threads} threads")
    axes[0].legend(fontsize=7)
    fig.suptitle("Thread-allocation study: baseline vs NUMA-aware",
                 fontsize=9)
    return _save(fig, path)
