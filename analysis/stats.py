"""Trace statistics: pure-numpy reductions over event columns.

Every function accepts a :class:`~analysis.loader.TraceRecord` (or a
bare ``TraceBuffer`` / ``SimResult`` where that makes sense) and
returns plain numpy arrays / dicts — no pandas, no matplotlib — so the
stats layer runs anywhere the simulator runs. Where a record has no
event trace, functions fall back to the always-on aggregate counters
(``SimResult.steal_hops`` / ``node_tasks`` / ``node_remote``) or raise
``ValueError`` when the statistic genuinely needs events.
"""

from __future__ import annotations

import numpy as np

__all__ = ["steal_hist", "locality", "queue_depth_timeline",
           "thread_utilization", "gantt", "summary"]


def _parts(rec):
    """(result, trace) from a TraceRecord / SimResult / TraceBuffer."""
    res = getattr(rec, "result", None)
    tr = getattr(rec, "trace", None)
    if res is None and hasattr(rec, "makespan"):
        res, tr = rec, getattr(rec, "trace", None)
    if tr is None and hasattr(rec, "st_dist"):
        tr = rec
    return res, tr


def _need_trace(rec, what: str):
    _, tr = _parts(rec)
    if tr is None:
        raise ValueError(f"{what} needs an event trace; this record has "
                         "none (run under SimParams(trace=True))")
    return tr


def steal_hist(rec, max_hop: "int | None" = None) -> np.ndarray:
    """Steal count per hop distance (index = hops, 0 = same node).

    Uses trace steal events when present, the aggregate
    ``SimResult.steal_hops`` counter otherwise; ``max_hop`` pads (or
    validates) the histogram length for cross-run alignment.
    """
    res, tr = _parts(rec)
    if tr is not None:
        h = np.bincount(np.asarray(tr.st_dist, dtype=np.int64),
                        minlength=(max_hop or 0) + 1)
    elif res is not None and getattr(res, "steal_hops", ()):
        h = np.asarray(res.steal_hops, dtype=np.int64)
        if max_hop is not None and len(h) < max_hop + 1:
            h = np.pad(h, (0, max_hop + 1 - len(h)))
    else:
        raise ValueError("record has neither a trace nor aggregate "
                         "steal_hops")
    return h.astype(np.int64)


def locality(rec) -> dict:
    """Per-NUMA-node locality: where work ran and what it paid.

    Returns ``tasks`` (committed executions per node), ``remote``
    (simulated time each node spent on remote-access penalties, from
    the aggregate counter), ``busy`` (total execution time per node,
    from the trace; NaN without one), and ``score`` — the fraction of
    a node's execution time *not* spent waiting on remote memory,
    ``1 - remote/busy`` in ``[0, 1]`` (1.0 = perfectly local). Idle
    nodes score NaN.
    """
    res, tr = _parts(rec)
    nn = 0
    if res is not None and getattr(res, "node_tasks", ()):
        nn = len(res.node_tasks)
    elif tr is not None:
        nn = int(tr.meta.get("num_nodes", 0)) or \
            (int(tr.ex_node.max()) + 1 if tr.n_exec else 1)
    if not nn:
        raise ValueError("record has neither a trace nor aggregate "
                         "node counters")
    tasks = np.zeros(nn, dtype=np.int64)
    remote = np.full(nn, np.nan)
    busy = np.full(nn, np.nan)
    if res is not None and getattr(res, "node_tasks", ()):
        tasks = np.asarray(res.node_tasks, dtype=np.int64)
        remote = np.asarray(res.node_remote, dtype=np.float64)
    elif tr is not None:
        np.add.at(tasks, np.asarray(tr.ex_node, dtype=np.int64), 1)
    if tr is not None:
        busy = np.zeros(nn)
        np.add.at(busy, np.asarray(tr.ex_node, dtype=np.int64),
                  np.asarray(tr.ex_end) - np.asarray(tr.ex_start))
    with np.errstate(invalid="ignore", divide="ignore"):
        score = 1.0 - remote / busy
    score = np.where(busy > 0, np.clip(score, 0.0, 1.0), np.nan)
    return dict(tasks=tasks, remote=remote, busy=busy, score=score)


def queue_depth_timeline(rec, bins: int = 120,
                         span: "float | None" = None):
    """Mean and max ready-queue depth over simulated time.

    Depth is sampled at each exec commit (the depth of the committing
    thread's deque under depth-first policies, of the shared queue
    otherwise). Returns ``(centers, mean, peak)``; bins with no
    samples are NaN (mean) / 0 (peak).
    """
    tr = _need_trace(rec, "queue_depth_timeline")
    t = np.asarray(tr.ex_start, dtype=np.float64)
    q = np.asarray(tr.ex_qlen, dtype=np.float64)
    hi = float(span if span is not None
               else (tr.ex_end.max() if tr.n_exec else 1.0)) or 1.0
    edges = np.linspace(0.0, hi, bins + 1)
    idx = np.clip(np.searchsorted(edges, t, side="right") - 1,
                  0, bins - 1)
    cnt = np.bincount(idx, minlength=bins).astype(np.float64)
    tot = np.bincount(idx, weights=q, minlength=bins)
    peak = np.zeros(bins)
    np.maximum.at(peak, idx, q)
    with np.errstate(invalid="ignore"):
        mean = np.where(cnt > 0, tot / np.maximum(cnt, 1), np.nan)
    centers = (edges[:-1] + edges[1:]) / 2
    return centers, mean, peak


def thread_utilization(rec, span: "float | None" = None) -> np.ndarray:
    """Busy fraction per thread: exec time / makespan."""
    tr = _need_trace(rec, "thread_utilization")
    res, _ = _parts(rec)
    nt = int(tr.meta.get("threads", 0)) or \
        (int(tr.ex_thread.max()) + 1 if tr.n_exec else 1)
    hi = span
    if hi is None:
        hi = getattr(res, "makespan", None) or \
            tr.meta.get("makespan") or \
            (float(tr.ex_end.max()) if tr.n_exec else 1.0)
    busy = np.zeros(nt)
    np.add.at(busy, np.asarray(tr.ex_thread, dtype=np.int64),
              np.asarray(tr.ex_end) - np.asarray(tr.ex_start))
    return busy / max(float(hi), 1e-300)


def gantt(rec) -> dict:
    """Per-thread execution intervals for Gantt rendering.

    ``{thread: (starts, durations, nodes)}`` — one entry per committed
    exec event, in commit order; ``nodes`` colors intervals by the
    NUMA node the work ran on.
    """
    tr = _need_trace(rec, "gantt")
    th = np.asarray(tr.ex_thread, dtype=np.int64)
    out = {}
    for t in np.unique(th):
        m = th == t
        out[int(t)] = (np.asarray(tr.ex_start)[m],
                       (np.asarray(tr.ex_end)
                        - np.asarray(tr.ex_start))[m],
                       np.asarray(tr.ex_node, dtype=np.int64)[m])
    return out


def summary(rec) -> dict:
    """One row of headline forensics for a record (textual reports)."""
    res, tr = _parts(rec)
    h = steal_hist(rec)
    steals = int(h.sum())
    hops = float((h * np.arange(len(h))).sum() / steals) if steals \
        else 0.0
    loc = locality(rec)
    score = loc["score"]
    row = dict(steals=steals, steal_hop_mean=round(hops, 3),
               locality=round(float(np.nanmean(score)), 4)
               if np.isfinite(score).any() else None)
    if tr is not None:
        util = thread_utilization(rec)
        row.update(events=int(tr.n_exec + tr.n_steal + tr.n_mig),
                   migrations=int(tr.n_mig),
                   util_mean=round(float(util.mean()), 4))
    if res is not None:
        row.update(makespan=round(float(res.makespan), 4),
                   speedup=None if res.speedup is None
                   else round(float(res.speedup), 3))
    return row
