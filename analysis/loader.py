"""Normalize trace sources into :class:`TraceRecord` values.

Three places an event trace can come from, one shape out:

* a **live run** — ``Machine.run(...)`` / ``Grid.run()`` under
  ``SimParams(trace=True)`` attaches a ``TraceBuffer`` to each
  ``SimResult`` (:func:`from_result` / :func:`from_grid`);
* a **sidecar file** — one ``.npz`` written by
  ``TraceBuffer.save_npz`` or spilled by the result store
  (:func:`from_npz`);
* a **durable-sweep journal** — a :class:`~repro.core.sim.ResultStore`
  JSONL plus its ``<stem>.traces/`` sidecar directory
  (:func:`from_store`); records keep their journal cell key.

A record's ``trace`` may be ``None`` (untraced cell, or a journal
entry whose sidecar was pruned); :mod:`analysis.stats` falls back to
the always-on aggregate counters on the ``SimResult`` where it can.
"""

from __future__ import annotations

import dataclasses
import os

__all__ = ["TraceRecord", "label_for", "from_result", "from_grid",
           "from_npz", "from_store"]


@dataclasses.dataclass
class TraceRecord:
    """One analyzed cell: a label, its metrics, and (maybe) its trace."""

    label: str
    result: "object | None" = None     # SimResult
    trace: "object | None" = None      # TraceBuffer
    key: "str | None" = None           # journal cell key, if journaled

    @property
    def meta(self) -> dict:
        return dict(getattr(self.trace, "meta", None) or {})

    def __repr__(self):
        tr = self.trace
        return (f"TraceRecord({self.label!r}, "
                f"trace={'yes' if tr is not None else 'no'})")


def label_for(key) -> str:
    """Human label for a :class:`~repro.core.sim.GridKey` cell."""
    lbl = (f"{key.workload}/{key.scheduler}/{key.context}"
           f"/T{key.threads}/s{key.seed}")
    if getattr(key, "faults", "none") != "none":
        lbl += f"/{key.faults}"
    return lbl


def from_result(result, label: str = "run") -> TraceRecord:
    """Wrap one live ``SimResult`` (trace attached or not)."""
    return TraceRecord(label, result=result,
                       trace=getattr(result, "trace", None))


def from_grid(results: dict) -> "list[TraceRecord]":
    """Records for every successful cell of a ``Grid.run()`` mapping.

    Failed cells (``CellError`` under ``strict=False``) are skipped —
    there is nothing to analyze in a cell that produced no events.
    """
    out = []
    for k, r in results.items():
        if not hasattr(r, "makespan"):
            continue
        out.append(from_result(r, label_for(k)))
    return out


def from_npz(path, label: "str | None" = None) -> TraceRecord:
    """Load one sidecar ``.npz`` trace file."""
    from repro.core.sim.trace import TraceBuffer
    tr = TraceBuffer.load_npz(path)
    if label is None:
        label = _meta_label(tr.meta) or \
            os.path.splitext(os.path.basename(os.fspath(path)))[0]
    return TraceRecord(label, trace=tr)


def from_store(store) -> "list[TraceRecord]":
    """Records for every journaled cell of a durable-sweep store.

    ``store`` is a :class:`~repro.core.sim.ResultStore` or a journal
    path. Each record carries its journal key; traces load from the
    ``<stem>.traces/`` sidecars where present.
    """
    opened = None
    if not hasattr(store, "get_trace"):
        from repro.core.sim import ResultStore
        store = opened = ResultStore(store)
    try:
        out = []
        for key, res in store.items():
            tr = store.get_trace(key)
            lbl = (_meta_label(getattr(tr, "meta", None))
                   or f"cell:{key[:12]}")
            out.append(TraceRecord(lbl, result=res, trace=tr, key=key))
        return out
    finally:
        if opened is not None:
            opened.close()


def _meta_label(meta) -> "str | None":
    """Label from trace metadata (scheduler/threads/seed), if present."""
    if not meta:
        return None
    parts = []
    if "scheduler" in meta:
        parts.append(str(meta["scheduler"]))
    if "threads" in meta:
        parts.append(f"T{meta['threads']}")
    if "seed" in meta:
        parts.append(f"s{meta['seed']}")
    return "/".join(parts) if parts else None
